"""Dynamic DDAST tuning (the paper's §8 future work), big.LITTLE manager
eligibility, and runtime-level property tests (random task graphs on the
REAL threaded runtime vs a sequential oracle)."""
import threading
import time

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import DDASTParams, TaskRuntime
from repro.core.autotune import DynamicTuner, TunerConfig
from repro.core.wd import DepMode

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


# ------------------------------------------------------------- autotune
def test_tuner_widens_managers_under_backlog():
    params = DDASTParams(max_ddast_threads=1, max_spins=1, max_ops_thread=8)
    rt = TaskRuntime(num_workers=4, mode="ddast", params=params)
    tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0, backlog_high=4))
    # simulate backlog without starting workers: enqueue many submits
    for i in range(100):
        rt.worker_queues[0].submit.push(
            type("M", (), {"wd": None})())
    before = rt.params.max_ddast_threads
    tuner.callback(0)
    assert rt.params.max_ddast_threads == before + 1
    assert rt.params.max_ops_thread > 8


def test_tuner_decays_when_calm():
    params = DDASTParams(max_ddast_threads=3, max_spins=1)
    rt = TaskRuntime(num_workers=8, mode="ddast", params=params)
    tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0))
    tuner._static_mgr = 1
    tuner.callback(0)                       # empty queues -> decay
    assert rt.params.max_ddast_threads == 2


def test_tuner_end_to_end_still_correct():
    from repro.core.taskgraph_apps import run_matmul
    params = DDASTParams(max_ddast_threads=1)
    a = np.random.RandomState(0).rand(64, 64).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="ddast", params=params) as rt:
        DynamicTuner(rt, TunerConfig(interval_s=0.0005))
        c = run_matmul(rt, a, a, bs=16)
    np.testing.assert_allclose(c, a @ a, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ big.LITTLE
def test_manager_eligibility_restricts_managers():
    seen = set()
    from repro.core.ddast import DDASTManager
    orig = DDASTManager.callback

    def spy(self, worker_id):
        before = self.messages_processed
        orig(self, worker_id)
        if self.messages_processed > before:
            seen.add(worker_id)

    DDASTManager.callback = spy
    try:
        with TaskRuntime(num_workers=4, mode="ddast",
                         manager_eligible={0, 1}) as rt:
            for i in range(200):
                rt.task(lambda: None, deps=[((i % 7,), INOUT)])
            rt.taskwait()
    finally:
        DDASTManager.callback = orig
    assert rt.stats.tasks_executed == 200
    # workers 2,3 must never have processed messages
    assert not (seen & {2, 3}), seen


# -------------------------------------------- runtime property testing
@st.composite
def task_program(draw):
    n_tasks = draw(st.integers(3, 18))
    n_regions = draw(st.integers(1, 5))
    prog = []
    for _ in range(n_tasks):
        k = draw(st.integers(1, min(2, n_regions)))
        regions = draw(st.lists(st.integers(0, n_regions - 1),
                                min_size=k, max_size=k, unique=True))
        modes = [draw(st.sampled_from([IN, OUT, INOUT])) for _ in regions]
        prog.append(list(zip(regions, modes)))
    return prog


@given(task_program(), st.sampled_from(["sync", "ddast"]))
@settings(max_examples=15, deadline=None)
def test_property_real_runtime_region_order(prog, mode):
    """On the REAL threaded runtime: for every region, writer tasks must
    execute in submission order and each reader sees the same last-writer
    as sequential execution would give it."""
    log_lock = threading.Lock()
    logs = {}

    def body(idx, deps):
        with log_lock:
            for region, m in deps:
                logs.setdefault(region, []).append(
                    (idx, "w" if m.writes else "r"))

    with TaskRuntime(num_workers=2, mode=mode) as rt:
        for idx, deps in enumerate(prog):
            rt.task(body, idx, deps, deps=deps, label=str(idx))
        rt.taskwait()
    assert rt.stats.tasks_executed == len(prog)
    for region, events in logs.items():
        writes = [i for i, k in events if k == "w"]
        assert writes == sorted(writes), (region, events)
        # readers: compare visible writer against sequential semantics
        seq_last = {}
        cur = -1
        for i, k in sorted(events, key=lambda e: e[0]):
            if k == "w":
                cur = i
            else:
                seq_last[i] = cur
        cur = -1
        for i, k in events:
            if k == "w":
                cur = i
            else:
                assert cur == seq_last[i], (region, events)
