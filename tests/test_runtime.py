"""Integration tests: the real threaded runtime in all four modes, the
paper's three applications, and the simulator's qualitative claims."""
import numpy as np
import pytest

from repro.core import (DDASTParams, RuntimeSimulator, SimCosts, TaskRuntime)
from repro.core.taskgraph_apps import (
    nbody_oracle, run_matmul, run_nbody, run_sparselu, sim_matmul_specs,
    sim_nbody_specs, sim_sparselu_specs, sparselu_oracle)

MODES = ("sync", "dast", "ddast", "sharded")


@pytest.mark.parametrize("mode", MODES)
def test_matmul_all_modes(mode):
    rng = np.random.RandomState(42)
    a = rng.rand(64, 64).astype(np.float32)
    b = rng.rand(64, 64).astype(np.float32)
    with TaskRuntime(num_workers=3, mode=mode) as rt:
        c = run_matmul(rt, a, b, bs=16)
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    assert rt.stats.tasks_executed == 4 ** 3


@pytest.mark.parametrize("mode", MODES)
def test_sparselu_all_modes(mode):
    rng = np.random.RandomState(0)
    n, bs = 96, 24
    m = rng.rand(n, n).astype(np.float32) + np.eye(n, dtype=np.float32) * n
    with TaskRuntime(num_workers=3, mode=mode) as rt:
        lu = run_sparselu(rt, m, bs)
    ref = sparselu_oracle(m, bs)
    np.testing.assert_allclose(lu, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("mode", MODES)
def test_nbody_nested_all_modes(mode):
    rng = np.random.RandomState(7)
    n, bs, steps = 64, 16, 3
    pos = rng.rand(n, 3).astype(np.float32)
    vel = np.zeros((n, 3), np.float32)
    mass = rng.rand(n).astype(np.float32)
    with TaskRuntime(num_workers=2, mode=mode) as rt:
        p, v = run_nbody(rt, pos, vel, mass, bs, steps)
    pr, vr = nbody_oracle(pos, vel, mass, steps)
    np.testing.assert_allclose(p, pr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(v, vr, rtol=1e-3, atol=1e-3)


def test_ddast_messages_flow_through_queues():
    a = np.eye(32, dtype=np.float32)
    with TaskRuntime(num_workers=2, mode="ddast") as rt:
        run_matmul(rt, a, a, bs=16)
    # every task went through submit+done messages handled by managers
    assert rt.stats.messages_processed >= 2 * rt.stats.tasks_executed
    assert rt.stats.ddast_callback_entries > 0


def test_sync_mode_uses_lock_directly():
    a = np.eye(32, dtype=np.float32)
    with TaskRuntime(num_workers=2, mode="sync") as rt:
        run_matmul(rt, a, a, bs=16)
    assert rt.stats.messages_processed == 0
    # one lock acquisition per submit + one per done
    assert rt.stats.lock_acquisitions == 2 * rt.stats.tasks_executed


def test_max_ddast_threads_limit():
    params = DDASTParams(max_ddast_threads=1)
    a = np.eye(32, dtype=np.float32)
    with TaskRuntime(num_workers=4, mode="ddast", params=params) as rt:
        run_matmul(rt, a, a, bs=16)
    assert rt.stats.tasks_executed == 8


# ---------------- simulator: the paper's qualitative claims -------------

def test_sim_deterministic():
    specs = lambda: sim_matmul_specs(6, dur_us=50)
    r1 = RuntimeSimulator(16, "ddast").run(specs())
    r2 = RuntimeSimulator(16, "ddast").run(specs())
    assert r1.makespan_us == r2.makespan_us
    assert r1.messages == r2.messages


def test_sim_contention_grows_with_cores_sync():
    lw = []
    for p in (8, 32):
        r = RuntimeSimulator(p, "sync").run(sim_matmul_specs(8, dur_us=100))
        lw.append(r.lock_wait_us)
    assert lw[1] > lw[0], "graph-lock contention should grow with cores (§1)"


def test_sim_ddast_beats_sync_at_scale():
    """Paper §6.1: DDAST outperforms the baseline for large thread counts."""
    s = RuntimeSimulator(64, "sync").run(sim_matmul_specs(8, dur_us=100))
    d = RuntimeSimulator(64, "ddast").run(sim_matmul_specs(8, dur_us=100))
    assert d.speedup > s.speedup


def test_sim_similar_at_small_scale():
    """Paper: similar performance with few threads / few tasks."""
    s = RuntimeSimulator(2, "sync").run(sim_matmul_specs(4, dur_us=100))
    d = RuntimeSimulator(2, "ddast").run(sim_matmul_specs(4, dur_us=100))
    assert abs(d.speedup - s.speedup) / s.speedup < 0.35


def test_sim_roof_vs_pyramid():
    """Fig 12: DDAST keeps fewer tasks in the dependence graph."""
    s = RuntimeSimulator(16, "sync").run(sim_matmul_specs(16, dur_us=400))
    d = RuntimeSimulator(16, "ddast").run(sim_matmul_specs(16, dur_us=400))
    assert d.max_in_graph < s.max_in_graph


def test_sim_nbody_submission_bound():
    """Fig 11 (FG): sync plateaus, ddast keeps scaling past it."""
    s = RuntimeSimulator(64, "sync").run(
        sim_nbody_specs(16, 4, dur_force=60, dur_update=15))
    d = RuntimeSimulator(64, "ddast").run(
        sim_nbody_specs(16, 4, dur_force=60, dur_update=15))
    assert d.speedup > s.speedup


def test_sim_sparselu_irregular_graph_runs():
    r = RuntimeSimulator(16, "ddast").run(sim_sparselu_specs(10))
    assert r.tasks > 100
    assert r.speedup > 4
