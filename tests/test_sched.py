"""Unified scheduling subsystem (core.sched): the shared DAG core
(bottom levels, band quantization, list schedule), the two-lane
StealDeque, CriticalPathPlacement over frozen replay graphs (the
4-policy x 3-app dependence-order oracle reused from test_replay.py,
plus zero-lock/zero-message steady state), the multi-recording replay
cache (A/B alternation, LRU bound, RuntimeStats.replay_cache_hits), the
shard-affine load cap, the O(n^2)-free overlap_collectives, and the
back-compat import surfaces."""
import threading

import pytest

from repro.core import RuntimeSimulator, TaskRuntime
from repro.core.engine import make_placement, make_policy
from repro.core.engine.replay import ReplayGraph
from repro.core.sched import (CriticalPathPlacement, DagNode,
                              RoundRobinPlacement, ShardAffinePlacement,
                              bottom_levels, build_arrays, ddast_schedule,
                              list_schedule, overlap_collectives,
                              quantize_bands)
from repro.core.shards import StealDeque
from repro.core.taskgraph_apps import sim_app_specs, sim_sparselu_specs
from repro.core.wd import DepMode, WorkDescriptor

# the oracle harness this file reuses (the issue's acceptance harness)
from test_replay import (ALL_MODES, APPS, _check_region_order, _count_tasks,
                         _iteration, _lockmsg, _run_specs_threaded,
                         _submission_events)

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


# ===================================================================
# DAG core
# ===================================================================
def test_bottom_levels_chain_and_diamond():
    #      0
    #     / \
    #    1   2     costs: 0->1, 1->2, 2->3, 3->4
    #     \ /
    #      3
    succs = [[1, 2], [3], [3], []]
    bl = bottom_levels(succs, [1.0, 2.0, 3.0, 4.0])
    assert bl == [1.0 + 3.0 + 4.0, 2.0 + 4.0, 3.0 + 4.0, 4.0]
    # unit costs: bottom level == longest remaining chain length
    assert bottom_levels(succs) == [3.0, 2.0, 2.0, 1.0]


def test_bottom_levels_is_reverse_topological():
    """The defining recurrence: bl[i] = cost[i] + max(bl[succ]) — and
    with positive costs every predecessor strictly dominates each of
    its successors (a valid reverse-topological priority)."""
    import random
    rng = random.Random(7)
    n = 60
    succs = [[] for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < 0.1:
                succs[i].append(j)
    costs = [rng.random() + 0.1 for _ in range(n)]
    bl = bottom_levels(succs, costs)
    for i in range(n):
        expect = costs[i] + max((bl[s] for s in succs[i]), default=0.0)
        assert abs(bl[i] - expect) < 1e-9
        for s in succs[i]:
            assert bl[i] > bl[s]


def test_bottom_levels_rejects_cycle():
    with pytest.raises(ValueError):
        bottom_levels([[1], [0]])


def test_quantize_bands_exact_and_capped():
    bands, nb = quantize_bands([1.0, 5.0, 3.0, 5.0], max_bands=32)
    assert nb == 3 and bands == [0, 2, 1, 2]
    levels = [float(i) for i in range(100)]
    bands, nb = quantize_bands(levels, max_bands=8)
    assert nb == 8 and max(bands) == 7 and min(bands) == 0
    # quantization is monotone: a higher level never gets a lower band
    for i in range(99):
        assert bands[i] <= bands[i + 1]
    assert quantize_bands([], 8) == ([], 0)


def test_list_schedule_matches_ddast_schedule():
    """ddast_schedule is now a thin name<->id wrapper over the shared
    list_schedule loop — same order, same guarantees."""
    nodes = [DagNode("a", cost=1.0), DagNode("b", deps=["a"], cost=2.0),
             DagNode("c", deps=["a"], cost=1.0),
             DagNode("d", deps=["b", "c"], cost=1.0)]
    _, succs, npreds = build_arrays(nodes)
    ids = list_schedule([n.cost for n in nodes], succs, npreds, 2)
    assert [nodes[i].name for i in ids] == ddast_schedule(nodes, 2)


# -------------------------------------------- overlap_collectives scaling
def _layered_dag(n):
    """n-node layered DAG with a collective after every compute node."""
    nodes = []
    for i in range(n):
        deps = [("c", i - 1)] if i else []
        nodes.append(DagNode(("c", i), cost=1.0, deps=deps))
        nodes.append(DagNode(("rs", i), cost=0.5, deps=[("c", i)],
                             kind="collective"))
    return nodes


def test_overlap_collectives_500_node_regression():
    """The historical implementation rescanned `out` with .index() per
    collective per dependence (O(n^2) on this shape); the position-map
    version must stay correct on a 500-node DAG: topological, every
    collective hoisted to right after its predecessor, and a
    permutation of the input order."""
    nodes = _layered_dag(250)           # 500 nodes, 250 collectives
    order = ddast_schedule(nodes, num_units=4)
    out = overlap_collectives(nodes, order)
    assert sorted(map(str, out)) == sorted(map(str, order))
    pos = {nm: i for i, nm in enumerate(out)}
    for n in nodes:
        for p in n.deps:
            assert pos[p] < pos[n.name]
    # each collective sits at the earliest legal slot: directly after
    # its (only) predecessor
    for i in range(250):
        assert pos[("rs", i)] == pos[("c", i)] + 1


def test_overlap_collectives_still_hoists_safely():
    nodes = [DagNode("c0"), DagNode("c1", deps=["c0"]),
             DagNode("rs0", deps=["c0"], kind="collective"),
             DagNode("c2", deps=["c1"])]
    order = ["c0", "c1", "c2", "rs0"]
    out = overlap_collectives(nodes, order)
    assert out.index("rs0") == out.index("c0") + 1


# ===================================================================
# two-lane StealDeque
# ===================================================================
def test_steal_deque_two_lane_semantics():
    dq = StealDeque(num_bands=3)
    dq.push("n1")
    dq.push("n2")
    dq.push_priority("p_low", 0)
    dq.push_priority("p_hi_a", 2)
    dq.push_priority("p_hi_b", 2)
    assert len(dq) == 5
    # owner: highest band first, LIFO within the band, normal lane last
    assert dq.pop() == "p_hi_b"
    # thief: highest band first, FIFO within the band
    assert dq.steal() == "p_hi_a"
    assert dq.steal() == "p_low"
    # normal lane unchanged: owner LIFO, thief FIFO
    assert dq.pop() == "n2"
    assert dq.steal() == "n1"
    assert dq.pop() is None and dq.steal() is None
    assert dq.pushed == 5 and dq.popped + dq.stolen == 5


def test_steal_deque_set_num_bands():
    dq = StealDeque()
    assert dq.num_bands == 0
    dq.push("x")
    dq.set_num_bands(4)
    assert dq.num_bands == 4 and dq.pop() == "x"


def test_steal_deque_owner_vs_thieves_stress():
    """Owner pops (both lanes) racing 4 thieves: every item retrieved
    exactly once, nothing lost, counters balance — the lock-free claim
    for the two-lane layout."""
    dq = StealDeque(num_bands=4)
    n_items = 4000
    got = []
    got_lock = threading.Lock()
    stop = threading.Event()

    def consume(fn):
        local = []
        while not stop.is_set() or len(dq):
            item = fn()
            if item is not None:
                local.append(item)
        with got_lock:
            got.extend(local)

    thieves = [threading.Thread(target=consume, args=(dq.steal,))
               for _ in range(4)]
    owner = threading.Thread(target=consume, args=(dq.pop,))
    for t in thieves + [owner]:
        t.start()
    for i in range(n_items):
        if i % 3 == 0:
            dq.push(i)
        else:
            dq.push_priority(i, i % 4)
    stop.set()
    for t in thieves + [owner]:
        t.join(timeout=10.0)
    assert sorted(got) == list(range(n_items))
    assert dq.pushed == n_items
    assert dq.popped + dq.stolen == n_items


# ===================================================================
# CriticalPathPlacement
# ===================================================================
def test_make_placement_critical_path():
    p = make_placement("critical_path", 3)
    assert isinstance(p, CriticalPathPlacement)
    assert isinstance(p, ShardAffinePlacement)   # degrade path inherited
    assert p._num_shards is None
    p2 = make_placement("critical_path", 3, num_shards=8)
    assert p2._num_shards == 8


def test_critical_path_degrades_outside_replay():
    """Without published priorities every push flows through the
    inherited shard-affine/round-robin path — usable on a live (or
    non-replay) runtime."""
    p = CriticalPathPlacement(3)
    assert not p.replay_priorities_active
    wds = [WorkDescriptor(func=None, deps=((("x", i), IN),))
           for i in range(6)]
    for wd in wds:
        p.push(wd)
    assert [len(d) for d in p.deques] == [2, 2, 2]
    assert p.priority_pushes == 0
    # push_replay without priorities degrades too
    p.push_replay(WorkDescriptor(func=None), sid=0)
    assert p.priority_pushes == 0 and p.ready_count() == 7


def test_critical_path_priorities_and_bands():
    p = CriticalPathPlacement(2, max_bands=8)
    p.set_replay_priorities([4.0, 1.0, 2.0, 4.0])
    assert p.replay_priorities_active
    assert p._bands_of == [2, 0, 1, 2]
    assert all(d.num_bands == 3 for d in p.deques)
    # pin both tasks to slot 0 via affinity so they share a deque
    dep = ((("r",), IN),)
    p.note_executed(WorkDescriptor(func=None, deps=dep), 0)
    wd_hi = WorkDescriptor(func=None, deps=dep, label="hi")
    wd_lo = WorkDescriptor(func=None, deps=dep, label="lo")
    p.push_replay(wd_lo, 1)
    p.push_replay(wd_hi, 0)
    assert p.priority_pushes == 2
    # within a deque the highest band pops first, regardless of push
    # order — and thieves scan the bands the same way
    assert p.pop(0) is wd_hi
    assert p.pop(1) is wd_lo            # reachable via steal, band-first
    p.clear_replay_priorities()
    assert not p.replay_priorities_active
    assert all(d.num_bands == 0 for d in p.deques)


def test_replay_publishes_valid_bottom_level_priorities():
    """After the freeze the placement holds one band per recorded task,
    and the banding is a valid reverse-topological bottom-level order:
    along every recorded edge the predecessor's band is >= the
    successor's (quantization is monotone), with strict domination of
    the raw levels."""
    with TaskRuntime(num_workers=2, mode="sync", replay=True,
                     placement="critical_path") as rt:
        out = []
        _iteration(rt, out, 20, regions=4)      # record + freeze
        g = rt.policy.replay_graph
        assert g is not None
        bands = rt.placement._bands_of
        assert bands is not None and len(bands) == g.n == 20
        levels = bottom_levels(g.succs, g.costs)
        for sid in range(g.n):
            for t in g.succs[sid]:
                assert levels[sid] > levels[t]
                assert bands[sid] >= bands[t]
        _iteration(rt, out, 20, regions=4)      # replay under priorities
        assert rt.placement.priority_pushes > 0
    assert rt.stats.tasks_executed == 40
    assert rt.stats.replay_iterations == 1


# ------------------------------------------------ the acceptance oracle
@pytest.mark.parametrize("mode", ALL_MODES)
@pytest.mark.parametrize("app,scale", APPS)
def test_critical_path_replay_matches_live_oracle(app, scale, mode):
    """test_replay.py's 4-policy x 3-app oracle, under critical-path
    placement: every iteration respects the dependence ordering and the
    steady-state path still costs ZERO graph-lock acquisitions and ZERO
    mailbox messages (the priority lane reintroduces no lock)."""
    specs = sim_app_specs(app, scale)
    ntasks = _count_tasks(specs)
    with TaskRuntime(num_workers=2, mode=mode, num_shards=8, replay=True,
                     placement="critical_path") as rt:
        for it in range(3):
            log = {}
            _run_specs_threaded(rt, specs, log=log)
            if app != "nbody":          # flat graphs: full ordering check
                _check_region_order(log, _submission_events(specs))
            if it == 0:
                base = _lockmsg(rt.policy)
        assert _lockmsg(rt.policy) == base, \
            "steady-state replay touched locks or mailboxes"
        assert rt.placement.priority_pushes > 0
    assert rt.stats.tasks_executed == 3 * ntasks
    assert rt.stats.replay_iterations == 2


@pytest.mark.parametrize("placement", ["round_robin", "critical_path"])
def test_sim_critical_path_replay_zero_cost_and_deterministic(placement):
    specs = sim_app_specs("sparselu", 8)
    r1 = RuntimeSimulator(8, "sharded", replay=True,
                          placement=placement).run(specs, iterations=3)
    r2 = RuntimeSimulator(8, "sharded", replay=True,
                          placement=placement).run(specs, iterations=3)
    assert r1.makespan_us == r2.makespan_us     # deterministic
    assert r1.iter_lock_acq[1:] == [0, 0]
    assert r1.iter_messages[1:] == [0, 0]


def test_sim_critical_path_beats_round_robin_on_imbalanced_lu():
    """The bench_sched.py CI gate, in miniature: replayed sparse-LU with
    imbalanced costs (heavy diagonal chain) schedules no worse under
    critical_path than under round_robin."""
    specs = sim_sparselu_specs(10, dur_lu0=600.0, dur_fwd=150.0,
                               dur_bdiv=150.0, dur_bmod=60.0)
    def steady(pl):
        r = RuntimeSimulator(8, "sharded", replay=True,
                             placement=pl).run(specs, iterations=4)
        return sum(r.iter_makespans_us[1:]) / 3
    assert steady("critical_path") <= steady("round_robin")


# ===================================================================
# multi-recording cache
# ===================================================================
def test_ab_alternation_replays_both_structures():
    """The ROADMAP follow-up: alternating structures stop re-recording
    every switch. After one recording of each, every further iteration
    replays from the cache — zero locks, zero messages, a cache hit per
    switch."""
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     replay=True) as rt:
        out = []

        def iter_a():
            _iteration(rt, out, 12, regions=3)

        def iter_b():                   # first task's key differs
            _iteration(rt, out, 12, regions=3, mode=IN, tag=1)

        iter_a()                        # record A, freeze A
        iter_b()                        # redispatch miss -> record B
        rep = rt.policy.stats()["replay"]
        assert rep["recordings"] == 2 and rep["cached_recordings"] == 2
        base = _lockmsg(rt.policy)
        for _ in range(3):
            iter_a()                    # cache switch B->A, full replay
            iter_b()                    # cache switch A->B, full replay
        assert _lockmsg(rt.policy) == base, \
            "alternating steady state touched locks or mailboxes"
        rep = rt.policy.stats()["replay"]
        assert rep["recordings"] == 2           # never re-recorded
        assert rep["replay_iterations"] == 6
        assert rep["cache_hits"] == 6           # one per switch
    assert rt.stats.tasks_executed == 12 * 8
    assert rt.stats.replay_cache_hits == 6
    assert rt.stats.replay_invalidations == 1   # B's initial redispatch


def test_cache_lru_bound():
    """More structures than cache slots: the LRU bound holds and evicted
    structures simply re-record when they return."""
    with TaskRuntime(num_workers=2, mode="ddast", replay=True) as rt:
        out = []
        pol = rt.policy
        assert pol.cache_size == 4

        def structure(tag):             # distinct first key per tag
            for i in range(6):
                rt.task(out.append, (tag, i),
                        deps=[((tag, i % 2), INOUT)])
            rt.taskwait()

        for tag in range(6):            # 6 distinct structures
            structure(tag)
        assert pol.stats()["replay"]["cached_recordings"] == 4
        assert pol.recordings == 6
        structure(0)                    # evicted: re-records
        assert pol.recordings == 7
        structure(5)                    # still cached: replays
        assert pol.recordings == 7
    assert rt.stats.tasks_executed == 6 * 8


def test_freeze_reuses_cached_graph_after_midstream_divergence():
    """A structure that diverges mid-iteration (shared prefix) cannot be
    cold-dispatched, but its re-recording hits the cache at freeze time
    and reuses the already-resolved graph object."""
    with TaskRuntime(num_workers=2, mode="sync", replay=True) as rt:
        out = []

        def iter_a():
            _iteration(rt, out, 10, regions=2)

        def iter_b():                   # same first 10 tasks, 4 extra
            _iteration(rt, out, 14, regions=2, tag=1)

        # note: iter_b's tasks 0..9 have identical keys to iter_a's
        iter_a()                        # record A
        iter_b()                        # diverges at task 10 -> retire
        iter_b()                        # re-record B (freeze: new graph)
        g_b = rt.policy.replay_graph
        iter_a()                        # diverges at quiescence (prefix)
        iter_a()                        # re-record A: freeze HITS cache
        g_a = rt.policy.replay_graph
        hits0 = rt.policy.replay_cache_hits
        assert hits0 >= 1               # the freeze-time reuse
        iter_b()                        # prefix replays, diverges, retire
        iter_b()                        # freeze hits cache: same B graph
        assert rt.policy.replay_graph is g_b
        assert rt.policy.replay_cache_hits > hits0
        assert g_a is not g_b
    expected = 10 * 1 + 14 * 2 + 10 * 2 + 14 * 2
    assert rt.stats.tasks_executed == expected


def test_iteration1_region_order_with_tag():
    """_iteration with a tag still orders per-region chains (guards the
    harness the cache tests above rely on)."""
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=4,
                     replay=True) as rt:
        out = []
        for _ in range(3):
            _iteration(rt, out, 18, regions=3, tag=7)
    by_region = {}
    for tag, i in out:
        assert tag == 7
        by_region.setdefault(i % 3, []).append(i)
    for r, vals in by_region.items():
        for it in range(3):
            chunk = vals[it * 6:(it + 1) * 6]
            assert chunk == sorted(chunk)


# ===================================================================
# shard-affine load cap
# ===================================================================
def test_shard_affine_load_cap_breaks_pileup():
    """One hot region previously funneled every dependent task onto the
    same slot; with the cap the overloaded deque sheds to round-robin."""
    p = ShardAffinePlacement(4)
    p.note_executed(WorkDescriptor(func=None, deps=((("hot",), IN),)), 1)
    for _ in range(32):
        p.push(WorkDescriptor(func=None, deps=((("hot",), INOUT),)))
    lens = [len(d) for d in p.deques]
    assert p.load_cap_skips > 0
    assert max(lens) < 32               # the pile-up is gone
    assert sum(lens) == 32
    # affinity still wins while the target is within budget
    assert p.affine_pushes > 0


def test_shard_affine_load_cap_two_slots():
    """The cap must also fire on a 2-slot ring (the target's own length
    is excluded from the average it is compared against)."""
    p = ShardAffinePlacement(2)
    p.note_executed(WorkDescriptor(func=None, deps=((("hot",), IN),)), 0)
    for _ in range(16):
        p.push(WorkDescriptor(func=None, deps=((("hot",), INOUT),)))
    assert p.load_cap_skips > 0
    assert len(p.deques[1]) > 0         # overflow shed to the other slot


def test_shard_affine_load_cap_inactive_when_balanced():
    p = ShardAffinePlacement(3)
    p.note_executed(WorkDescriptor(func=None, deps=((("r",), IN),)), 2)
    for _ in range(3):                  # below _LOAD_CAP_MIN
        p.push(WorkDescriptor(func=None, deps=((("r",), INOUT),)))
    assert p.load_cap_skips == 0
    assert len(p.deques[2]) == 3


# ===================================================================
# back-compat import surfaces
# ===================================================================
# -------------------------------------------------- global priority pop
def test_global_priority_pop_inverted_per_deque_order():
    """Regression (PR-4 follow-up): per-deque order inverts the global
    order — slot 0's own deque holds only a LOW band task while a HIGH
    band task sits in slot 1's deque. With per-deque banding pop(0)
    would start the low task; the band-indexed global counters must
    steer it to steal the high task first."""
    pl = CriticalPathPlacement(2)
    pl.set_replay_priorities([1.0, 5.0])        # sid0 band0, sid1 band1
    lo = WorkDescriptor(func=None, label="lo")
    hi = WorkDescriptor(func=None, label="hi")
    pl.deques[0].push_priority(lo, 0)
    pl.deques[1].push_priority(hi, 1)
    assert pl.pop(0) is hi                       # global best band wins
    assert pl.global_band_steals == 1
    assert pl.pop(0) is lo
    assert pl.pop(0) is None


def test_global_priority_pop_prefers_own_deque_on_equal_band():
    pl = CriticalPathPlacement(2)
    pl.set_replay_priorities([5.0, 5.0])
    own = WorkDescriptor(func=None, label="own")
    other = WorkDescriptor(func=None, label="other")
    pl.deques[0].push_priority(own, 0)
    pl.deques[1].push_priority(other, 0)
    assert pl.pop(0) is own                      # no pointless steal
    assert pl.global_band_steals == 0


def test_global_band_counters_are_resilient_hints():
    """A stale counter (drifted by a benign race) must cost at most a
    wasted scan — never strand or lose a task."""
    pl = CriticalPathPlacement(2)
    pl.set_replay_priorities([1.0, 5.0])
    lo = WorkDescriptor(func=None, label="lo")
    pl.deques[0].push_priority(lo, 0)
    pl._band_counts[1] += 3                      # phantom high band
    assert pl.pop(0) is lo                       # falls through cleanly
    pl._band_counts[0] -= 5                      # phantom emptiness
    hi = WorkDescriptor(func=None, label="hi")
    pl.deques[1].push_priority(hi, 1)
    assert pl.pop(0) is hi
    assert pl.pop(1) is None


def test_backcompat_engine_placement_imports():
    from repro.core.engine.placement import (CriticalPathPlacement as C2,
                                             PlacementPolicy,
                                             RoundRobinPlacement as R2,
                                             ShardAffinePlacement as S2,
                                             make_placement as mp2)
    assert C2 is CriticalPathPlacement
    assert R2 is RoundRobinPlacement and S2 is ShardAffinePlacement
    assert isinstance(mp2("round_robin", 2), PlacementPolicy)


def test_backcompat_static_sched_imports():
    from repro.core.static_sched import (DagNode as D2,
                                         ddast_schedule as dd2,
                                         overlap_collectives as oc2)
    assert D2 is DagNode
    assert dd2 is ddast_schedule and oc2 is overlap_collectives
    nodes = [D2("a"), D2("b", deps=["a"])]
    assert dd2(nodes) == ["a", "b"]


# ===================================================================
# hypothesis property tests (guarded like test_engine.py)
# ===================================================================
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 5), st.booleans()),
                    min_size=1, max_size=24),
           st.integers(2, 5))
    @settings(max_examples=12, deadline=None)
    def test_property_critical_path_replay_preserves_order(tasks, regions):
        """Random task streams (region id, writes?) over 3 iterations
        under critical-path replay: per-region writer order and last-
        writer visibility hold every iteration — the placement may only
        reorder what the DAG allows."""
        with TaskRuntime(num_workers=2, mode="sync", replay=True,
                         placement="critical_path") as rt:
            for _ in range(3):
                log = {}
                lock = threading.Lock()

                def body(i, region, writes):
                    with lock:
                        log.setdefault(region, []).append(
                            (i, "w" if writes else "r"))

                sub = {}
                for i, (rid, writes) in enumerate(tasks):
                    region = (rid % regions,)
                    mode = INOUT if writes else IN
                    sub.setdefault(region, []).append(
                        (i, "w" if writes else "r"))
                    rt.task(body, i, region, writes,
                            deps=[(region, mode)])
                rt.taskwait()
                _check_region_order(log, sub)
        assert rt.stats.tasks_executed == 3 * len(tasks)

    @given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=64),
           st.integers(1, 16))
    @settings(max_examples=50, deadline=None)
    def test_property_quantize_bands_monotone(levels, max_bands):
        bands, nb = quantize_bands(levels, max_bands)
        assert len(bands) == len(levels)
        assert 0 < nb <= max_bands
        assert all(0 <= b < nb for b in bands)
        for (la, ba) in zip(levels, bands):
            for (lb, bb) in zip(levels, bands):
                if la < lb:
                    assert ba <= bb

    @given(st.integers(2, 40), st.floats(0.05, 0.3), st.integers(0, 999))
    @settings(max_examples=30, deadline=None)
    def test_property_bottom_levels_recurrence(n, density, seed):
        import random
        rng = random.Random(seed)
        succs = [[] for _ in range(n)]
        for i in range(n):
            for j in range(i + 1, n):
                if rng.random() < density:
                    succs[i].append(j)
        costs = [rng.random() + 0.05 for _ in range(n)]
        bl = bottom_levels(succs, costs)
        for i in range(n):
            expect = costs[i] + max((bl[s] for s in succs[i]), default=0.0)
            assert abs(bl[i] - expect) < 1e-9
