"""Unit + property tests for the dependence graph (paper §2.2.1 semantics)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.depgraph import DependenceGraph
from repro.core.wd import DepMode, TaskState, WorkDescriptor

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


def wd(deps, label="t"):
    return WorkDescriptor(func=None, deps=deps, label=label)


def test_raw_dependence():
    g = DependenceGraph()
    w = wd([("a", OUT)])
    r = wd([("a", IN)])
    assert g.submit(w) is True
    assert g.submit(r) is False          # RAW: reader waits for writer
    assert r.num_predecessors == 1
    newly = g.complete(w)
    assert newly == [r]


def test_war_and_waw():
    g = DependenceGraph()
    w1 = wd([("a", OUT)])
    r1 = wd([("a", IN)])
    r2 = wd([("a", IN)])
    w2 = wd([("a", OUT)])
    g.submit(w1)
    g.submit(r1)
    g.submit(r2)
    assert g.submit(w2) is False
    # WAW on w1 + WAR on both readers
    assert w2.num_predecessors == 3
    g.complete(w1)
    assert w2.num_predecessors == 2
    g.complete(r1)
    g.complete(r2)
    assert w2.state == TaskState.READY


def test_independent_regions_parallel():
    g = DependenceGraph()
    tasks = [wd([((i,), INOUT)]) for i in range(10)]
    assert all(g.submit(t) for t in tasks)


def test_chain_in_order():
    g = DependenceGraph()
    chain = [wd([("c", INOUT)], label=f"c{i}") for i in range(5)]
    ready = [g.submit(t) for t in chain]
    assert ready == [True, False, False, False, False]
    for i in range(4):
        newly = g.complete(chain[i])
        assert newly == [chain[i + 1]]


def test_in_graph_counting():
    g = DependenceGraph()
    t1, t2 = wd([("x", INOUT)]), wd([("x", INOUT)])
    g.submit(t1)
    g.submit(t2)
    assert g.in_graph == 2 and g.max_in_graph == 2
    g.complete(t1)
    assert g.in_graph == 1
    g.complete(t2)
    assert g.in_graph == 0 and g.max_in_graph == 2


# ---- property: any interleaving-legal completion order preserves the
# sequential-consistency order on every region ---------------------------

@st.composite
def random_task_set(draw):
    n_tasks = draw(st.integers(2, 25))
    n_regions = draw(st.integers(1, 6))
    tasks = []
    for _ in range(n_tasks):
        n_deps = draw(st.integers(1, min(3, n_regions)))
        regions = draw(st.lists(st.integers(0, n_regions - 1),
                                min_size=n_deps, max_size=n_deps,
                                unique=True))
        modes = [draw(st.sampled_from([IN, OUT, INOUT])) for _ in regions]
        tasks.append(list(zip(regions, modes)))
    return tasks


@given(random_task_set(), st.randoms(use_true_random=False))
@settings(max_examples=60, deadline=None)
def test_property_execution_respects_program_order(task_deps, rng):
    """Execute in ANY legal order (randomly chosen among ready tasks):
    for every region, writers must execute in submission order, and every
    reader must see exactly the writes submitted before it."""
    g = DependenceGraph()
    wds = [wd(d, label=str(i)) for i, d in enumerate(task_deps)]
    ready = []
    for t in wds:
        if g.submit(t):
            ready.append(t)
    executed = []
    log = {}  # region -> list of (task_index, 'r'/'w')
    while ready:
        t = ready.pop(rng.randrange(len(ready)))
        executed.append(t)
        for region, mode in t.deps:
            if mode.writes:
                log.setdefault(region, []).append((int(t.label), "w"))
            elif mode.reads:
                log.setdefault(region, []).append((int(t.label), "r"))
        ready.extend(g.complete(t))
    assert len(executed) == len(wds), "deadlock: not all tasks executed"
    for region, events in log.items():
        writes = [i for i, k in events if k == "w"]
        assert writes == sorted(writes), \
            f"region {region}: writers out of program order: {writes}"
        last_w = -1
        for i, k in events:
            if k == "w":
                last_w = max(last_w, i)
            else:
                # reader index i must come after all writers with idx < i
                # i.e. no pending earlier writer may execute after it
                pass
        # stronger check: replay sequentially and compare visible writer
        seq_last = {}
        cur = -1
        for i, k in sorted(events, key=lambda e: e[0]):
            if k == "w":
                cur = i
            else:
                seq_last[i] = cur
        cur = -1
        for i, k in events:
            if k == "w":
                cur = i
            else:
                assert cur == seq_last[i], (
                    f"region {region}: reader {i} saw writer {cur}, "
                    f"sequential order implies {seq_last[i]}")
