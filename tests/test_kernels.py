"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes, plus hypothesis
property tests of the attention contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm_pallas
from repro.kernels.ssm_scan import selective_scan_pallas, ssm_scan_pallas

KEY = jax.random.key(0)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ------------------------------------------------------- flash attention
ATTN_SHAPES = [
    # (B, S, T, nq, nkv, hd)
    (1, 128, 128, 4, 4, 64),
    (2, 128, 128, 8, 2, 64),       # GQA 4:1
    (1, 256, 256, 4, 1, 128),      # MQA
    (2, 64, 64, 14, 2, 64),        # qwen2-0.5b head layout
    (1, 96, 96, 4, 4, 64),         # non-multiple of block
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_matches_ref(shape, dtype, causal):
    b, s, t, nq, nkv, hd = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, s, nq, hd), dtype)
    k = rand(k2, (b, t, nkv, hd), dtype)
    v = rand(k3, (b, t, nkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_window_and_softcap():
    b, s, nq, nkv, hd = 1, 256, 4, 2, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, s, nq, hd))
    k = rand(k2, (b, s, nkv, hd))
    v = rand(k3, (b, s, nkv, hd))
    out = flash_attention(q, k, v, causal=True, window=64, softcap=50.0,
                          blk_q=64, blk_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=64, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]),
       st.booleans())
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(b, s, heads, causal):
    nq, nkv = heads
    hd = 64
    k1, k2, k3 = jax.random.split(jax.random.key(b * s + nq), 3)
    q = rand(k1, (b, s, nq, hd))
    k = rand(k2, (b, s, nkv, hd))
    v = rand(k3, (b, s, nkv, hd))
    out = flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # attention outputs are convex combinations of V rows
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


# -------------------------------------------------------- selective scan
SCAN_SHAPES = [(1, 128, 64, 8), (2, 256, 128, 16), (1, 512, 256, 16)]


@pytest.mark.parametrize("shape", SCAN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_matches_ref(shape, dtype):
    b, s, d, n = shape
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (b, s, d), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, s, d))).astype(dtype) * 0.1
    a_log = rand(ks[2], (d, n), jnp.float32) * 0.1
    bmat = rand(ks[3], (b, s, n), dtype, 0.5)
    cmat = rand(ks[4], (b, s, n), dtype, 0.5)
    dvec = jnp.ones((d,), jnp.float32) * 0.5
    y, h = selective_scan_pallas(x, dt, a_log, bmat, cmat, dvec,
                                 blk_t=64, blk_d=64, interpret=True)
    yr, hr = ref.selective_scan_ref(x, dt, a_log, bmat, cmat, dvec)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


def test_selective_scan_carries_state_across_blocks():
    """Recurrence must be continuous across time-block boundaries."""
    b, s, d, n = 1, 256, 64, 8
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (b, s, d))
    dt = jnp.full((b, s, d), 0.05)
    a_log = jnp.zeros((d, n))
    bmat = jnp.ones((b, s, n)) * 0.3
    cmat = jnp.ones((b, s, n)) * 0.3
    dvec = jnp.zeros((d,))
    y1, _ = selective_scan_pallas(x, dt, a_log, bmat, cmat, dvec,
                                  blk_t=32, blk_d=64, interpret=True)
    y2, _ = selective_scan_pallas(x, dt, a_log, bmat, cmat, dvec,
                                  blk_t=256, blk_d=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 64, 128), (2, 128, 512)])
def test_linear_scan_matches_ref(shape):
    b, s, d = shape
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(rand(k1, (b, s, d)))
    bx = rand(k2, (b, s, d))
    got = ssm_scan_pallas(a, bx, blk_t=32, blk_d=128, interpret=True)
    want = ref.ssm_scan_ref(a, bx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- moe gemm
MOE_SHAPES = [(4, 64, 128, 256), (8, 128, 256, 128), (3, 100, 96, 72)]


@pytest.mark.parametrize("shape", MOE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_matches_ref(shape, dtype):
    e, c, d, f = shape
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (e, c, d), dtype, 0.3)
    w = rand(k2, (e, d, f), dtype, 0.3)
    got = moe_gemm_pallas(x, w, blk_c=64, blk_d=64, blk_f=64,
                          interpret=True)
    want = ref.moe_gemm_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_moe_gemm_expert_isolation():
    """Each expert's output must depend only on its own slice."""
    e, c, d, f = 4, 32, 64, 64
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (e, c, d))
    w = rand(k2, (e, d, f))
    base = moe_gemm_pallas(x, w, interpret=True)
    x2 = x.at[2].set(999.0)
    pert = moe_gemm_pallas(x2, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(pert[0]))
    np.testing.assert_array_equal(np.asarray(base[3]), np.asarray(pert[3]))
    assert not np.allclose(np.asarray(base[2]), np.asarray(pert[2]))
