"""Record-and-replay subsystem (core.engine.replay): the replay-vs-live
oracle (identical dependence orderings and ready-order constraints for
all four wrapped policies on the three paper apps over >= 3 iterations,
with ZERO graph-lock acquisitions and ZERO mailbox messages on the
steady-state path), invalidation (changed dep mode / added task /
changed region / fewer tasks -> fall back to live analysis and
re-record), generation-counter latch reuse, plus the satellite features
that rode along: Done batching, shard-id affinity keying, and per-shard
stat carry across resize."""
import threading

import pytest

from repro.core import (DynamicTuner, RuntimeSimulator, TaskRuntime,
                        TunerConfig)
from repro.core.engine import (ReplayPolicy, ShardAffinePlacement,
                               make_placement, make_policy)
from repro.core.engine.replay import ReplayGraph
from repro.core.shards import stable_region_hash
from repro.core.taskgraph_apps import sim_app_specs
from repro.core.wd import DepMode, TaskState, WorkDescriptor

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT

ALL_MODES = ("sync", "dast", "ddast", "sharded")
APPS = [("matmul", 3), ("nbody", 3), ("sparselu", 5)]


# ------------------------------------------------------------ helpers
def _run_specs_threaded(rt, specs, log=None):
    """Execute a SimTaskSpec graph on the real runtime (recursing into
    nested children). With `log`, each task body records (label, r/w)
    events per region under a lock."""
    lock = threading.Lock()

    def body(spec):
        if log is not None:
            with lock:
                for region, m in spec.deps:
                    log.setdefault(region, []).append(
                        (spec.label, "w" if m.writes else "r"))
        if spec.children:
            for ch in spec.children:
                rt.task(body, ch, deps=ch.deps, label=ch.label)
            rt.taskwait()

    for s in specs:
        rt.task(body, s, deps=s.deps, label=s.label)
    rt.taskwait()


def _submission_events(specs):
    events = {}
    for s in specs:
        for region, m in s.deps:
            events.setdefault(region, []).append(
                (s.label, "w" if m.writes else "r"))
    return events


def _check_region_order(events, sub_events):
    """Writers executed in submission order; every read saw the
    sequentially-correct last writer."""
    for region, evs in events.items():
        sub = sub_events[region]
        writes = [l for l, k in evs if k == "w"]
        assert writes == [l for l, k in sub if k == "w"], (region, evs)
        seq_last = {}
        cur = None
        for l, k in sub:
            if k == "w":
                cur = l
            else:
                seq_last[l] = cur
        cur = None
        for l, k in evs:
            if k == "w":
                cur = l
            else:
                assert cur == seq_last[l], (region, evs)


def _count_tasks(specs):
    n = 0
    stack = [list(specs)]
    while stack:
        for s in stack.pop():
            n += 1
            if s.children:
                stack.append(s.children)
    return n


def _lockmsg(policy):
    st = policy.stats()
    return st["lock_acquisitions"], st["messages_processed"]


# ------------------------------------------------- the acceptance oracle
@pytest.mark.parametrize("mode", ALL_MODES)
@pytest.mark.parametrize("app,scale", APPS)
def test_replay_matches_live_oracle(app, scale, mode):
    """>= 3 iterations of each paper app under every wrapped policy:
    every iteration respects the dependence ordering, and from iteration
    2 on the policy performs ZERO graph-lock acquisitions and ZERO
    mailbox messages (the issue's acceptance criterion)."""
    specs = sim_app_specs(app, scale)
    ntasks = _count_tasks(specs)
    with TaskRuntime(num_workers=2, mode=mode, num_shards=8,
                     replay=True) as rt:
        for it in range(3):
            log = {}
            _run_specs_threaded(rt, specs, log=log)
            if app != "nbody":          # flat graphs: full ordering check
                _check_region_order(log, _submission_events(specs))
            if it == 0:
                base = _lockmsg(rt.policy)
        assert _lockmsg(rt.policy) == base, \
            "steady-state replay touched locks or mailboxes"
        rep = rt.policy.stats()["replay"]
        assert rep["state"] == "replaying"
        assert rep["replay_iterations"] == 2
        assert rep["invalidations"] == 0
        assert rep["recorded_tasks"] == ntasks
    assert rt.stats.tasks_executed == 3 * ntasks
    assert rt.stats.replay_iterations == 2
    assert rt.stats.replayed_tasks == 2 * ntasks


@pytest.mark.parametrize("mode", ALL_MODES)
def test_runtime_stats_show_zero_cost_steady_state(mode):
    """RuntimeStats-level acceptance: a 3-iteration replay run performs
    exactly the lock acquisitions and messages of a 1-iteration live
    run — the two replayed iterations add zero of either."""
    specs = sim_app_specs("sparselu", 5)

    def run(iters, replay):
        with TaskRuntime(num_workers=2, mode=mode, num_shards=4,
                         replay=replay) as rt:
            for _ in range(iters):
                _run_specs_threaded(rt, specs)
        return rt.stats

    once = run(1, replay=False)
    thrice = run(3, replay=True)
    assert thrice.tasks_executed == 3 * once.tasks_executed
    assert thrice.lock_acquisitions == once.lock_acquisitions
    assert thrice.messages_processed == once.messages_processed
    assert thrice.replay_iterations == 2
    assert thrice.replayed_tasks == 2 * once.tasks_executed


@pytest.mark.parametrize("mode", ALL_MODES)
def test_sim_replay_matches_live(mode):
    """Simulated driver: replay over 3 iterations executes the same
    tasks, pays the live protocol exactly once (iteration 1), and its
    steady-state iterations cost 0 lock acquisitions / 0 messages and
    less virtual time than live iterations."""
    specs = sim_app_specs("matmul", 4)
    kw = dict(num_shards=8)
    live = RuntimeSimulator(4, mode, **kw).run(specs, iterations=3)
    rep = RuntimeSimulator(4, mode, replay=True, **kw).run(
        specs, iterations=3)
    once = RuntimeSimulator(4, mode, **kw).run(specs)
    assert rep.tasks == live.tasks == 3 * once.tasks
    assert rep.messages == once.messages
    assert rep.iter_lock_acq[1:] == [0, 0]
    assert rep.iter_messages[1:] == [0, 0]
    # exec order of every replay iteration respects the region protocol
    per_iter = len(rep.exec_order) // 3
    sub = _submission_events(specs)
    for it in range(3):
        order = rep.exec_order[it * per_iter:(it + 1) * per_iter]
        pos = {label: i for i, label in enumerate(order)}
        evs = {r: sorted(e, key=lambda x: pos[x[0]])
               for r, e in sub.items()}
        _check_region_order(evs, sub)
    # the win: steady-state replay iterations are faster than live ones
    assert min(rep.iter_makespans_us[1:]) < min(live.iter_makespans_us[1:])


def test_sim_replay_nested_nbody():
    specs = sim_app_specs("nbody", 4)   # nested timestep parents
    live = RuntimeSimulator(4, "ddast").run(specs, iterations=3)
    rep = RuntimeSimulator(4, "ddast", replay=True).run(specs, iterations=3)
    assert rep.tasks == live.tasks
    assert rep.iter_lock_acq[1:] == [0, 0]
    assert rep.iter_messages[1:] == [0, 0]


# ------------------------------------------------------- invalidation
def _iteration(rt, out, n, regions, mode=INOUT, tag=0):
    for i in range(n):
        rt.task(out.append, (tag, i), deps=[((i % regions,), mode)])
    rt.taskwait()


@pytest.mark.parametrize("mutate", ["mode", "region", "added"])
def test_invalidation_falls_back_and_rerecords(mutate):
    """A structural divergence (changed dep mode, changed region, added
    task) falls back to live analysis and re-records the new structure —
    which then replays lock- and message-free again. A divergence on the
    FIRST submission (the changed-mode case: task 0's key differs)
    re-records in the SAME iteration (nothing was replayed yet); a
    mid-iteration divergence finishes the replayed prefix under replay,
    live-analyzes the suffix, and re-records on the next iteration."""
    first_task_diverges = mutate == "mode"
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     replay=True) as rt:
        out = []

        def iter_a():
            _iteration(rt, out, 16, regions=4)

        def iter_b():
            if mutate == "mode":
                _iteration(rt, out, 16, regions=4, mode=IN, tag=1)
            elif mutate == "region":
                _iteration(rt, out, 16, regions=5, tag=1)
            else:
                _iteration(rt, out, 17, regions=4, tag=1)

        iter_a()                            # record
        iter_a()                            # replay
        assert rt.policy.stats()["replay"]["replay_iterations"] == 1
        iter_b()                            # diverge
        rep = rt.policy.stats()["replay"]
        assert rep["invalidations"] == 1
        if first_task_diverges:
            # redispatched to RECORDING before anything replayed: the
            # new structure froze at this very iteration's quiescence
            assert rep["state"] == "replaying"
            assert rep["recordings"] == 2
        else:
            assert rep["state"] == "recording"
            iter_b()                        # re-record the new structure
        base = _lockmsg(rt.policy)
        iter_b()                            # replay the new structure
        assert _lockmsg(rt.policy) == base
        rep = rt.policy.stats()["replay"]
        assert rep["state"] == "replaying"
        assert rep["recordings"] == 2
        # the old structure was retired into the cache, not dropped
        assert rep["cached_recordings"] == 2
    expected = 16 * 2 + (17 if mutate == "added" else 16) * \
        (2 if first_task_diverges else 3)
    assert rt.stats.tasks_executed == expected
    assert rt.stats.replay_invalidations == 1


def test_fallback_preserves_dependence_order():
    """The diverging suffix must still respect dependences against the
    replayed prefix: a suffix chain on a prefix region only runs after
    all replayed predecessors completed (they have: fallback buffers per
    namespace until the replayed siblings drain)."""
    with TaskRuntime(num_workers=3, mode="sync", replay=True) as rt:
        out = []

        def record_iter(extra):
            for i in range(12):
                rt.task(out.append, i, deps=[(("r", i % 3), INOUT)])
            if extra:                   # divergence: 6 extra chained tasks
                for i in range(12, 18):
                    rt.task(out.append, i, deps=[(("r", i % 3), INOUT)])
            rt.taskwait()

        record_iter(False)
        out.clear()
        record_iter(True)               # replays 12, falls back for 6
        # per-region submission order must hold across the replay/live seam
        by_region = {}
        for v in out:
            by_region.setdefault(v % 3, []).append(v)
        for r, vals in by_region.items():
            assert vals == sorted(vals), (r, vals)
    assert rt.stats.tasks_executed == 12 + 18


def test_fewer_tasks_iteration_is_correct_then_invalidates():
    """An iteration submitting a strict prefix of the recording executes
    correctly (two-phase latches keep never-submitted tasks unready) and
    invalidates at its quiescence."""
    with TaskRuntime(num_workers=2, mode="ddast", replay=True) as rt:
        out = []
        _iteration(rt, out, 10, regions=3)
        _iteration(rt, out, 10, regions=3)
        assert rt.policy.stats()["replay"]["state"] == "replaying"
        _iteration(rt, out, 6, regions=3)   # prefix only
        rep = rt.policy.stats()["replay"]
        assert rep["state"] == "recording"
        assert rep["invalidations"] == 1
        _iteration(rt, out, 6, regions=3)   # re-record
        _iteration(rt, out, 6, regions=3)   # replay
        assert rt.policy.stats()["replay"]["state"] == "replaying"
    assert rt.stats.tasks_executed == 10 * 2 + 6 * 3


def test_nested_divergence_in_child_namespace():
    """Divergence inside a nested parent's namespace (different children
    on iteration 2) while sibling namespaces replay."""
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     replay=True) as rt:
        out = []

        def parent_body(n, tag):
            for i in range(n):
                rt.task(out.append, (tag, i), deps=[((tag, i % 2), INOUT)])
            rt.taskwait()

        def iteration(n_b):
            rt.task(parent_body, 4, "a", deps=[(("pa",), INOUT)])
            rt.task(parent_body, n_b, "b", deps=[(("pb",), INOUT)])
            rt.taskwait()

        iteration(4)                    # record: both parents 4 children
        iteration(4)                    # replay
        iteration(6)                    # parent b diverges at child 5
        assert rt.policy.stats()["replay"]["invalidations"] == 1
        iteration(6)
        iteration(6)
        assert rt.policy.stats()["replay"]["state"] == "replaying"
    assert rt.stats.tasks_executed == 2 * (2 + 8) + 3 * (2 + 10)


# ------------------------------------------- generation-counter reuse
def test_generation_counter_latch_reuse_stress():
    """Many replay iterations must reuse the SAME frozen graph and
    latches (reset via the generation counter, not re-allocation) and
    stay lock- and message-free throughout."""
    iters = 30
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=4,
                     replay=True) as rt:
        out = []
        _iteration(rt, out, 24, regions=6)
        graph0 = rt.policy.replay_graph
        latch0 = graph0.latches[0]
        base = _lockmsg(rt.policy)
        for _ in range(iters - 1):
            _iteration(rt, out, 24, regions=6)
            assert rt.policy.replay_graph is graph0
            assert rt.policy.replay_graph.latches[0] is latch0
        assert _lockmsg(rt.policy) == base
        assert rt.policy.stats()["replay"]["replay_iterations"] == iters - 1
    assert rt.stats.tasks_executed == 24 * iters
    # every iteration's per-region order was correct (4 entries per
    # region per iteration, in submission order within the iteration)
    by_region = {}
    for tag, i in out:
        by_region.setdefault(i % 6, []).append(i)
    for r, vals in by_region.items():
        assert len(vals) == 4 * iters
        for it in range(iters):
            chunk = vals[it * 4:(it + 1) * 4]
            assert chunk == sorted(chunk), (r, it, chunk)


def test_replay_graph_freeze_matches_depgraph_semantics():
    """Freeze-time analysis uses the shared RAW/WAW/WAR helper: chain +
    diamond resolve to the same edges a live DependenceGraph computes."""
    # namespace -1 (root): w(a) -> r(a) x2 -> w(a)  (diamond via WAR+RAW)
    kids = [
        ((("a",), OUT),),               # sid 0: writer
        ((("a",), IN),),                # sid 1: reader (RAW on 0)
        ((("a",), IN),),                # sid 2: reader (RAW on 0)
        ((("a",), INOUT),),             # sid 3: WAW on 0 + WAR on 1,2
    ]
    children = {-1: [(k, i) for i, k in enumerate(kids)]}
    g = ReplayGraph(children, [-1, -1, -1, -1], set())
    assert g.preds == [0, 1, 1, 3]
    assert sorted(g.succs[0]) == [1, 2, 3]
    assert g.succs[1] == [3] and g.succs[2] == [3]
    assert g.total_edges == 5
    assert [l.init for l in g.latches] == [1, 2, 2, 4]


def test_make_policy_replay_registry():
    pol = make_policy("replay:sharded", 3, num_shards=4)
    assert isinstance(pol, ReplayPolicy)
    assert pol.name == "replay(sharded)"
    assert pol.num_shards == 4          # delegation to the wrapped policy
    pol2 = make_policy("ddast", 3, replay=True)
    assert isinstance(pol2, ReplayPolicy)
    assert make_policy("sync", 3).__class__.__name__ == "SyncPolicy"
    with pytest.raises(ValueError):
        make_policy("replay:nope", 3)


# -------------------------------------------------- tuner interaction
def test_tuner_does_not_resize_while_recording_live():
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     replay=True)
    tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0,
                                         shard_min_messages=1))
    pol = rt.policy
    # mid-recording: submit and fully drain so pending/in_graph are 0,
    # but the iteration (and with it the recording) is still open
    for i in range(8):
        wd = WorkDescriptor(func=None, deps=(((i % 2,), INOUT),),
                            parent=rt._root)
        pol.submit(wd, rt.num_workers)
    while True:
        pol.drain_all()
        wd = rt.placement.pop(rt.num_workers)
        if wd is None:
            if not pol.pending() and not pol.in_graph():
                break
            continue
        wd.mark_finished()
        pol.complete(wd, rt.num_workers)
    assert pol.recording_live
    before = pol.num_shards
    tuner.quiescent_callback(0)
    assert pol.num_shards == before     # guarded: no resize, no sample
    assert tuner._shard_prev_metric is None
    pol.notify_quiescent(True)          # freeze
    assert not pol.recording_live
    assert pol.replay_state == "replaying"


def test_tuner_with_replay_end_to_end():
    """Tuner + replay coexist: replay steady state generates no new
    messages, so the shard hill-climb simply starves (no spurious
    resizes), and correctness holds."""
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     replay=True) as rt:
        DynamicTuner(rt, TunerConfig(interval_s=0.0, shard_min_messages=8))
        out = []
        for _ in range(4):
            _iteration(rt, out, 20, regions=5)
        assert rt.policy.stats()["replay"]["replay_iterations"] == 3
    assert rt.stats.tasks_executed == 80


# ---------------------------------------------------- Done batching
def test_done_batch_single_mailbox_entry():
    """5 independent completions on one shard, batched: ONE
    DoneBatchMessage entry, latch arithmetic balances, graph empties."""
    pol = make_policy("sharded", 2, num_shards=1, batch_size=8)
    root = WorkDescriptor(func=None, label="root")
    wds = [WorkDescriptor(func=None, deps=(((("r", i)), INOUT),),
                          parent=root) for i in range(5)]
    for wd in wds:
        pol.submit(wd, 0)
    pol.flush(0)
    pol.drain_all()
    assert pol.stats()["messages_processed"] == 1   # one submit batch
    assert all(wd.state == TaskState.READY for wd in wds)
    for wd in wds:                      # all 5 Dones buffered, no flush
        wd.mark_finished()
        pol.complete(wd, 0)
    assert pol.stats()["messages_processed"] == 1
    pol.flush(0)
    pol.drain_all()
    assert all(wd.state == TaskState.COMPLETED for wd in wds)
    assert pol.in_graph() == 0
    # 1 submit batch + 1 done batch (5 dones shipped as one entry)
    assert pol.stats()["messages_processed"] == 2


def test_done_batching_reduces_sim_messages():
    specs = sim_app_specs("matmul", 4)
    unb = RuntimeSimulator(4, "sharded", num_shards=16).run(specs)
    bat = RuntimeSimulator(4, "sharded", num_shards=16,
                           batch_size=8).run(specs)
    assert bat.tasks == unb.tasks
    # Both sides batch: total entries must undercut unbatched by more
    # than the submit side alone ever could (the unbatched done side is
    # half the 360-entry total; submit-only batching therefore bottoms
    # out at > 180). The exact count is bounded below by distinct
    # shards-per-flush, so assert against that structural floor.
    assert bat.messages < unb.messages - unb.messages // 4


def test_done_batching_threaded_order_and_liveness():
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=8,
                     batch_size=4) as rt:
        out = []
        for i in range(300):
            rt.task(out.append, i, deps=[((i % 11,), INOUT)])
        rt.taskwait()
    assert rt.stats.tasks_executed == 300
    by_region = {}
    for v in out:
        by_region.setdefault(v % 11, []).append(v)
    for r, vals in by_region.items():
        assert vals == sorted(vals), (r, vals[:8])


def test_pending_counts_done_buffers():
    pol = make_policy("sharded", 2, num_shards=2, batch_size=16)
    root = WorkDescriptor(func=None, label="root")
    wd = WorkDescriptor(func=None, deps=((("r",), INOUT),), parent=root)
    pol.submit(wd, 0)
    pol.flush(0)
    pol.drain_all()
    wd.mark_finished()
    pol.complete(wd, 0)                 # buffered Done
    assert pol.pending() == 1
    pol.flush(0)
    pol.drain_all()
    assert pol.pending() == 0
    assert wd.state == TaskState.COMPLETED


# ------------------------------------------- shard-id affinity keying
def test_affinity_keyed_by_shard_id():
    p = ShardAffinePlacement(3, num_shards=4)
    shard = stable_region_hash(("x", 0)) % 4
    # a DIFFERENT region on the same shard inherits the affinity
    other = next((("x", i) for i in range(1, 64)
                  if stable_region_hash(("x", i)) % 4 == shard))
    p.note_executed(WorkDescriptor(func=None, deps=(((("x", 0)), IN),)), 2)
    wd = WorkDescriptor(func=None, deps=((other, IN),))
    assert p.preferred_slot(wd) == 2
    # map is hard-bounded by the shard count on region churn
    for i in range(1000):
        p.note_executed(
            WorkDescriptor(func=None, deps=(((("r", i)), IN),)), i % 3)
    assert len(p._affinity) <= 4


def test_make_placement_passes_num_shards():
    p = make_placement("shard_affine", 3, num_shards=8)
    assert p._num_shards == 8
    p2 = make_placement("shard_affine", 3)
    assert p2._num_shards is None       # exact-region keying preserved
    assert make_placement("round_robin", 3, num_shards=8) is not None


def test_shard_keying_only_for_shard_backed_modes():
    """Only shard-partitioned policies switch affinity to shard-id
    keying; sync/dast/ddast keep the documented exact-region keying."""
    rt = TaskRuntime(num_workers=4, mode="ddast",
                     placement="shard_affine")
    assert rt.placement._num_shards is None
    rt2 = TaskRuntime(num_workers=4, mode="sharded", num_shards=8,
                      placement="shard_affine")
    assert rt2.placement._num_shards == 8


def test_resize_rekeys_shard_affinity():
    """ShardedPolicy.resize retunes the affinity partition function so
    placement keys keep matching the graph's shard assignment."""
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     placement="shard_affine")
    pl, pol = rt.placement, rt.policy
    pl.note_executed(WorkDescriptor(func=None, deps=((("q",), IN),)), 1)
    assert pl._num_shards == 4 and len(pl._affinity) == 1
    assert pol.resize(8)
    assert pl._num_shards == 8
    assert len(pl._affinity) == 0       # stale buckets dropped
    # exact-region placements are NOT converted by a resize
    direct = ShardAffinePlacement(3)
    direct.set_num_shards(8)
    assert direct._num_shards is None


# ------------------------------- multi-iteration paper apps (numeric)
def test_run_matmul_epochs_replay_numeric():
    import numpy as np
    from repro.core.taskgraph_apps import run_matmul_epochs
    a = np.random.RandomState(7).rand(48, 48).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=4,
                     replay=True) as rt:
        c = run_matmul_epochs(rt, a, a, bs=16, epochs=3)
        base = _lockmsg(rt.policy)
        # a fresh call (new C blocks, new closures, SAME structure)
        # keeps replaying: zero protocol cost for both extra epochs
        c2 = run_matmul_epochs(rt, a, a, bs=16, epochs=2)
        assert _lockmsg(rt.policy) == base
    np.testing.assert_allclose(c, 3 * (a @ a), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(c2, 2 * (a @ a), rtol=1e-3, atol=1e-3)
    assert rt.stats.replay_iterations == 4
    assert rt.stats.replay_invalidations == 0


def test_run_sparselu_epochs_replay_numeric():
    import numpy as np
    from repro.core.taskgraph_apps import (run_sparselu_epochs,
                                           sparselu_oracle)
    rng = np.random.RandomState(11)
    mats = [(rng.rand(48, 48).astype(np.float32)
             + 48 * np.eye(48, dtype=np.float32)) for _ in range(3)]
    with TaskRuntime(num_workers=3, mode="ddast", replay=True) as rt:
        outs = run_sparselu_epochs(rt, mats, bs=16)
    for m, out in zip(mats, outs):
        np.testing.assert_allclose(out, sparselu_oracle(m, 16),
                                   rtol=2e-3, atol=2e-3)
    assert rt.stats.replay_iterations == 2      # epochs 2 and 3 replayed
    assert rt.stats.replay_invalidations == 0


def test_run_nbody_epochs_replay_numeric():
    import numpy as np
    from repro.core.taskgraph_apps import nbody_oracle, run_nbody_epochs
    rng = np.random.RandomState(5)
    n, bs, steps = 32, 8, 4
    pos = rng.rand(n, 3).astype(np.float32)
    vel = np.zeros((n, 3), dtype=np.float32)
    mass = rng.rand(n).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=4,
                     replay=True) as rt:
        p, v = run_nbody_epochs(rt, pos, vel, mass, bs, timesteps=steps)
    po, vo = nbody_oracle(pos, vel, mass, steps)
    np.testing.assert_allclose(p, po, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(v, vo, rtol=1e-3, atol=1e-4)
    # nested epochs: each timestep after the first replays
    assert rt.stats.replay_iterations == steps - 1
    assert rt.stats.replay_invalidations == 0


# ------------------------------------- resize carries per-shard stats
def test_resize_carries_per_shard_counters():
    pol = make_policy("sharded", 2, num_shards=4)
    root = WorkDescriptor(func=None, label="root")
    wds = [WorkDescriptor(func=None, deps=(((i,), INOUT),), parent=root)
           for i in range(12)]
    for wd in wds:
        pol.submit(wd, 0)
    pol.drain_all()
    for wd in wds:
        wd.mark_finished()
        pol.complete(wd, 0)
    pol.drain_all()
    st0 = pol.stats()
    msgs0 = st0["shard_messages"]
    assert sum(msgs0) == st0["messages_processed"] > 0
    assert pol.resize(8)
    st1 = pol.stats()
    # the per-shard history survived the swap (padded to the new width)
    assert sum(st1["shard_messages"]) == sum(msgs0)
    assert len(st1["shard_messages"]) == 8
    assert st1["messages_processed"] == st0["messages_processed"]
    # and keeps accumulating after the resize
    wd = WorkDescriptor(func=None, deps=((("z",), INOUT),), parent=root)
    pol.submit(wd, 0)
    pol.drain_all()
    st2 = pol.stats()
    assert sum(st2["shard_messages"]) == sum(msgs0) + 1
