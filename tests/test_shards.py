"""Sharded dependence-manager subsystem (core.shards): unit tests for the
lock-free primitives, the shard router's join protocol, oracle tests that
``mode="sharded"`` matches ``mode="sync"`` bit-for-bit on all three paper
apps, dependence-ordering checks across all four modes, DDASTManager
drain_all / big.LITTLE gating coverage, stats aggregation, and the
simulator mirror."""
import numpy as np
import pytest

from repro.core import (DDASTParams, RuntimeSimulator, TaskRuntime)
from repro.core.messages import DoneTaskMessage, SubmitTaskMessage
from repro.core.shards import (AtomicCounter, ShardRouter,
                               ShardedDependenceGraph, StealDeque,
                               stable_region_hash)
from repro.core.taskgraph_apps import (
    run_matmul, run_nbody, run_sparselu, sim_matmul_specs,
    sim_sparselu_specs, sparselu_oracle)
from repro.core.wd import DepMode, TaskState, WorkDescriptor

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT

ALL_MODES = ("sync", "dast", "ddast", "sharded")


# ------------------------------------------------------------ primitives
def test_steal_deque_owner_lifo_thief_fifo():
    d = StealDeque()
    for i in range(5):
        d.push(i)
    assert d.pop() == 4            # owner: newest (LIFO)
    assert d.steal() == 0          # thief: oldest (FIFO)
    assert d.steal() == 1
    assert d.pop() == 3
    assert len(d) == 1
    assert d.pop() == 2
    assert d.pop() is None and d.steal() is None


def test_atomic_counter_join_semantics():
    c = AtomicCounter(3)
    assert c.add(2 - 1) == 4       # shard with 2 local preds
    assert c.add(0 - 1) == 3       # shard with 0 local preds
    assert c.add(0 - 1) == 2       # last latch unit
    assert c.add(-1) == 1
    assert c.add(-1) == 0          # unique zero observation
    assert c.value == 0


def test_stable_region_hash_deterministic_and_spread():
    assert stable_region_hash(("M", 3, 4)) == stable_region_hash(("M", 3, 4))
    assert stable_region_hash(("M", 3, 4)) != stable_region_hash(("M", 4, 3))
    buckets = {stable_region_hash(("C", i, j)) % 8
               for i in range(8) for j in range(8)}
    assert len(buckets) == 8       # all shards populated by a block grid


# --------------------------------------------------------- router unit
def _drain_router(router):
    n = 0
    while router.pending():
        n += router.drain_all()
    return n


def test_router_chain_orders_and_completes():
    """a(INOUT r) -> b(INOUT r): b must wait for a's Done, then both
    complete and leave the graph."""
    graph = ShardedDependenceGraph(num_shards=4)
    ready = []
    router = ShardRouter(graph, on_ready=ready.append)
    root = WorkDescriptor(func=None, label="root")
    a = WorkDescriptor(func=None, deps=((("r",), INOUT),), parent=root)
    b = WorkDescriptor(func=None, deps=((("r",), INOUT),), parent=root)
    router.route_submit(a)
    router.route_submit(b)
    _drain_router(router)
    assert ready == [a]
    assert a.state == TaskState.READY and b.state == TaskState.SUBMITTED
    router.route_done(a)
    _drain_router(router)
    assert ready == [a, b]
    assert a.state == TaskState.COMPLETED
    router.route_done(b)
    _drain_router(router)
    assert b.state == TaskState.COMPLETED
    assert graph.in_graph == 0
    assert graph.max_in_graph == 2
    assert graph.total_edges == 1


def test_router_cross_shard_task_waits_for_all_portions():
    """A task whose deps live on several shards becomes ready only after
    every shard portion is processed (the submit latch). Drives the
    blocking mailboxes directly (delegation=False); the same latch under
    delegation is covered in test_delegation.py."""
    graph = ShardedDependenceGraph(num_shards=8)
    ready = []
    router = ShardRouter(graph, on_ready=ready.append, delegation=False)
    root = WorkDescriptor(func=None, label="root")
    deps = tuple(((f"r{i}",), INOUT) for i in range(6))
    wd = WorkDescriptor(func=None, deps=deps, parent=root)
    router.route_submit(wd)
    shard_ids = graph.shards_for(wd)
    assert len(shard_ids) > 1, "test needs a genuinely cross-shard task"
    # process all but one shard portion: still not ready
    for s in shard_ids[:-1]:
        mb = router.mailboxes[s]
        assert mb.try_claim()
        try:
            router.process(s, mb.pop())
        finally:
            mb.release()
    assert wd.state == TaskState.SUBMITTED and not ready
    # last portion flips it
    s = shard_ids[-1]
    mb = router.mailboxes[s]
    assert mb.try_claim()
    try:
        router.process(s, mb.pop())
    finally:
        mb.release()
    assert wd.state == TaskState.READY and ready == [wd]


def test_router_dependence_free_task_ready_immediately():
    graph = ShardedDependenceGraph(num_shards=4)
    ready = []
    router = ShardRouter(graph, on_ready=ready.append)
    wd = WorkDescriptor(func=None, label="free")
    router.route_submit(wd)
    assert ready == [wd] and router.pending() == 0
    router.route_done(wd)
    assert wd.state == TaskState.COMPLETED and graph.in_graph == 0


def test_shard_mailbox_exclusivity():
    graph = ShardedDependenceGraph(num_shards=2)
    router = ShardRouter(graph, on_ready=lambda wd: None)
    mb = router.mailboxes[0]
    assert mb.try_claim()
    assert not mb.try_claim()      # second manager bounced
    mb.release()
    assert mb.try_claim()
    mb.release()


# ----------------------------------------- oracle: sharded == sync apps
def test_sharded_matches_sync_matmul():
    rng = np.random.RandomState(42)
    a = rng.rand(64, 64).astype(np.float32)
    b = rng.rand(64, 64).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="sync") as rt:
        ref = run_matmul(rt, a, b, bs=16)
    with TaskRuntime(num_workers=3, mode="sharded") as rt:
        out = run_matmul(rt, a, b, bs=16)
    np.testing.assert_array_equal(out, ref)
    assert rt.stats.tasks_executed == 4 ** 3


def test_sharded_matches_sync_sparselu():
    rng = np.random.RandomState(0)
    n, bs = 96, 24
    m = rng.rand(n, n).astype(np.float32) + np.eye(n, dtype=np.float32) * n
    with TaskRuntime(num_workers=3, mode="sync") as rt:
        ref = run_sparselu(rt, m, bs)
    with TaskRuntime(num_workers=3, mode="sharded") as rt:
        out = run_sparselu(rt, m, bs)
    np.testing.assert_array_equal(out, ref)
    # and both against the numpy oracle
    np.testing.assert_allclose(out, sparselu_oracle(m, bs),
                               rtol=2e-3, atol=2e-3)


def test_sharded_matches_sync_nbody_nested():
    rng = np.random.RandomState(7)
    n, bs, steps = 64, 16, 2
    pos = rng.rand(n, 3).astype(np.float32)
    vel = np.zeros((n, 3), np.float32)
    mass = rng.rand(n).astype(np.float32)
    with TaskRuntime(num_workers=2, mode="sync") as rt:
        p_ref, v_ref = run_nbody(rt, pos, vel, mass, bs, steps)
    with TaskRuntime(num_workers=2, mode="sharded") as rt:
        p, v = run_nbody(rt, pos, vel, mass, bs, steps)
    np.testing.assert_array_equal(p, p_ref)
    np.testing.assert_array_equal(v, v_ref)


# ------------------------------- dependence ordering across ALL 4 modes
@pytest.mark.parametrize("mode", ALL_MODES)
def test_sparselu_pattern_dependence_order_all_modes(mode):
    """Run the sparse-LU dependence *pattern* (from the sim specs) on the
    real runtime with logging bodies: per region, writers must execute in
    submission order and each read must see the sequentially-correct last
    writer — identical dependence ordering in all four organizations."""
    import threading
    specs = sim_sparselu_specs(6)
    log_lock = threading.Lock()
    events = {}                    # region -> [(submit_idx, kind)]

    def body(idx, deps):
        with log_lock:
            for region, m in deps:
                events.setdefault(region, []).append(
                    (idx, "w" if m.writes else "r"))

    with TaskRuntime(num_workers=3, mode=mode) as rt:
        for idx, spec in enumerate(specs):
            rt.task(body, idx, spec.deps, deps=spec.deps, label=spec.label)
        rt.taskwait()
    assert rt.stats.tasks_executed == len(specs)
    for region, evs in events.items():
        writes = [i for i, k in evs if k == "w"]
        assert writes == sorted(writes), (mode, region, evs)
        seq_last = {}
        cur = -1
        for i, k in sorted(evs, key=lambda e: e[0]):
            if k == "w":
                cur = i
            else:
                seq_last[i] = cur
        cur = -1
        for i, k in evs:
            if k == "w":
                cur = i
            else:
                assert cur == seq_last[i], (mode, region, evs)


# --------------------------------------- DDASTManager coverage gaps
def test_drain_all_processes_submit_and_done_queues():
    """drain_all (used by the dast loop and shutdown edges) must empty
    every queue and make/complete tasks accordingly. Exercised without
    starting worker threads so the drain itself does all the work."""
    rt = TaskRuntime(num_workers=2, mode="ddast")
    wds = [rt.task(lambda: None, deps=[(("r", i % 3), INOUT)])
           for i in range(10)]
    assert rt._pending_msgs() == 10
    n = rt.ddast.drain_all()
    assert n == 10
    assert rt.ddast.messages_processed == 10
    assert rt._pending_msgs() == 0
    # one chain per region: exactly 3 heads ready
    assert rt.ready_count() == 3
    # finish the ready heads through the Done path
    for wd in wds[:3]:
        wd.mark_finished()
        rt.worker_queues[rt.num_workers].done.push(DoneTaskMessage(wd))
    assert rt.ddast.drain_all() == 3
    assert all(wd.state == TaskState.COMPLETED for wd in wds[:3])
    assert rt.ready_count() == 6   # next link of each chain became ready


def test_drain_all_sharded_routes_through_shards():
    # blocking-mailbox baseline: with delegation the producer combines
    # eagerly and nothing would sit in a mailbox to observe
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     delegation=False)
    for i in range(12):
        rt.task(lambda: None, deps=[(("r", i % 4), INOUT)])
    assert rt.shard_router.pending() == 12
    n = rt.ddast.drain_all()
    assert n == 12
    assert rt.shard_router.pending() == 0
    assert rt.ready_count() == 4   # one chain head per region
    assert rt.shard_router.messages_processed == 12


def test_manager_eligible_gates_callback_directly():
    """big.LITTLE gating: an ineligible worker's callback must return
    without processing anything; eligible workers and the main thread
    (id == num_workers) must process."""
    rt = TaskRuntime(num_workers=4, mode="ddast", manager_eligible={0})
    rt.task(lambda: None, deps=[(("r",), INOUT)])
    rt.ddast.callback(2)                      # LITTLE core: gated out
    assert rt.ddast.messages_processed == 0
    assert rt.ddast.callback_entries == 0
    rt.ddast.callback(0)                      # big core: processes
    assert rt.ddast.messages_processed == 1
    rt.task(lambda: None, deps=[(("r2",), INOUT)])
    rt.ddast.callback(4)                      # main thread: always eligible
    assert rt.ddast.messages_processed == 2


def test_manager_eligible_gates_sharded_mode_end_to_end():
    a = np.eye(32, dtype=np.float32)
    with TaskRuntime(num_workers=4, mode="sharded",
                     manager_eligible={0, 1}) as rt:
        c = run_matmul(rt, a, a, bs=16)
    np.testing.assert_array_equal(c, a)
    assert rt.stats.tasks_executed == 8


# ----------------------------------------------------- stats aggregation
def test_sharded_stats_aggregate_per_shard_counters():
    a = np.eye(64, dtype=np.float32)
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=4) as rt:
        run_matmul(rt, a, a, bs=16)
    st = rt.stats
    # every task needs >= 1 submit + >= 1 done portion
    assert st.messages_processed >= 2 * st.tasks_executed
    assert st.messages_processed == sum(st.shard_messages)
    assert len(st.shard_messages) == 4
    assert len(st.shard_lock_wait_s) == 4
    assert st.lock_acquisitions == st.messages_processed
    assert abs(st.lock_wait_s - sum(st.shard_lock_wait_s)) < 1e-12
    assert st.max_in_graph >= 1
    assert st.total_edges > 0


def test_sharded_runtime_respects_max_ddast_threads():
    params = DDASTParams(max_ddast_threads=1)
    a = np.eye(32, dtype=np.float32)
    with TaskRuntime(num_workers=4, mode="sharded", params=params) as rt:
        run_matmul(rt, a, a, bs=16)
    assert rt.stats.tasks_executed == 8


def test_shard_assignment_reproducible_across_runtimes():
    """Shard choice hashes the bare region (not the process-global
    parent wd_id), so per-shard statistics are comparable between two
    runs of the same workload in one process."""
    def run():
        with TaskRuntime(num_workers=2, mode="sharded", num_shards=4) as rt:
            for i in range(60):
                rt.task(lambda: None, deps=[((i % 13,), INOUT)])
            rt.taskwait()
        return rt.stats.shard_messages
    assert run() == run()


def test_num_shards_validation():
    for bad in (0, -3):
        with pytest.raises(ValueError):
            TaskRuntime(num_workers=2, mode="sharded", num_shards=bad)
        with pytest.raises(ValueError):
            RuntimeSimulator(2, "sharded", num_shards=bad)


# ------------------------------------------------------ simulator mirror
def test_sim_sharded_deterministic():
    r1 = RuntimeSimulator(16, "sharded").run(sim_matmul_specs(6, dur_us=50))
    r2 = RuntimeSimulator(16, "sharded").run(sim_matmul_specs(6, dur_us=50))
    assert r1.makespan_us == r2.makespan_us
    assert r1.messages == r2.messages
    assert r1.lock_wait_us == r2.lock_wait_us


def test_sim_sharded_lower_lock_wait_than_sync_at_8_workers():
    """The ISSUE acceptance shape: matmul graph, 8 workers, summed
    per-shard lock wait < sync's global-lock wait."""
    s = RuntimeSimulator(8, "sync").run(sim_matmul_specs(8, dur_us=100))
    sh = RuntimeSimulator(8, "sharded", num_shards=16).run(
        sim_matmul_specs(8, dur_us=100))
    assert sh.tasks == s.tasks == 8 ** 3
    assert sh.lock_wait_us < s.lock_wait_us


def test_sim_sharded_completes_all_apps():
    from repro.core.taskgraph_apps import sim_app_specs
    for app in ("matmul", "nbody", "sparselu"):
        r = RuntimeSimulator(16, "sharded").run(sim_app_specs(app, 6))
        assert r.tasks > 0
        assert r.speedup > 1, (app, r.speedup)


def test_sim_sharded_shard_count_sweep_reduces_contention():
    # the blocking lock model (delegation=False): more shards -> less
    # contention; under delegation shard lock waits are ~0 by design
    # (see test_delegation.py)
    waits = []
    for nshards in (1, 16):
        r = RuntimeSimulator(8, "sharded", num_shards=nshards,
                             delegation=False).run(
            sim_matmul_specs(8, dur_us=100))
        waits.append(r.lock_wait_us)
    assert waits[1] < waits[0], waits
