"""Multi-tenant job-scope subsystem (core.scopes): the scope-isolation
oracle (two concurrent scopes running matmul + sparse-LU produce
byte-identical per-scope results and the same dependence orderings as
each run alone, across all four policies on BOTH drivers), per-scope
record-and-replay steady state (two tenants submitting structurally
identical graphs concurrently each replay with ZERO lock acquisitions
and ZERO mailbox messages per iteration, in the simulator AND on real
threads), the FairAdmission layer (weighted-deficit grants, shared
admission window, per-scope max_inflight backpressure), the region
keying shim, and the serve-engine satellites (per-engine request ids,
JobScope-backed client queues)."""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (FairAdmission, RuntimeSimulator, ScopedRegion,
                        SimTaskSpec, TaskRuntime, scoped_deps)
from repro.core.engine import ReplayPolicy
from repro.core.sched.placement import RoundRobinPlacement
from repro.core.shards import stable_region_hash
from repro.core.taskgraph_apps import (run_matmul, run_sparselu,
                                       sim_app_specs, sparselu_oracle)
from repro.core.wd import DepMode, WorkDescriptor

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT

ALL_MODES = ("sync", "dast", "ddast", "sharded")


# ------------------------------------------------------------ helpers
def _relabel(specs, prefix):
    """Copy a spec graph with scope-distinct labels (recursing into
    nested children) so per-scope tasks are identifiable in the shared
    exec_order."""
    out = []
    for s in specs:
        out.append(SimTaskSpec(
            dur=s.dur, deps=s.deps,
            children=_relabel(s.children, prefix) if s.children else None,
            label=f"{prefix}.{s.label}"))
    return out


def _submission_events(specs):
    events = {}
    for s in specs:
        for region, m in s.deps:
            events.setdefault(region, []).append(
                (s.label, "w" if m.writes else "r"))
    return events


def _check_region_order(events, sub_events):
    """Writers executed in submission order; every read saw the
    sequentially-correct last writer (same oracle the engine tests use
    for solo runs — passing it means the scope's dependence ordering is
    exactly what it would be alone)."""
    for region, evs in events.items():
        sub = sub_events[region]
        writes = [l for l, k in evs if k == "w"]
        assert writes == [l for l, k in sub if k == "w"], (region, evs)
        seq_last = {}
        cur = None
        for l, k in sub:
            if k == "w":
                cur = l
            else:
                seq_last[l] = cur
        cur = None
        for l, k in evs:
            if k == "w":
                cur = l
            else:
                assert cur == seq_last[l], (region, evs)


def _check_scope_order(result, specs):
    labels = {s.label for s in specs}
    pos = {l: i for i, l in enumerate(result.exec_order) if l in labels}
    assert len(pos) == len(labels)
    sub = _submission_events(specs)
    events = {r: sorted(evs, key=lambda e: pos[e[0]])
              for r, evs in sub.items()}
    _check_region_order(events, sub)


_SOLO = {}


def _solo_refs():
    """Byte-exact single-tenant references, computed once (the kernels
    are deterministic, so any mode/driver gives the same bytes)."""
    if not _SOLO:
        rng = np.random.RandomState(7)
        a = rng.rand(16, 16).astype(np.float32)
        b = rng.rand(16, 16).astype(np.float32)
        n = 20
        m = rng.rand(n, n).astype(np.float32) + np.eye(n, dtype=np.float32) * n
        with TaskRuntime(num_workers=2, mode="sync") as rt:
            _SOLO["a"], _SOLO["b"], _SOLO["m"] = a, b, m
            _SOLO["mm"] = run_matmul(rt, a, b, bs=4)
            _SOLO["lu"] = run_sparselu(rt, m, bs=4)
    return _SOLO


# ------------------------------------------------------ keying shim
def test_scoped_deps_keying_shim():
    deps = [(("A", 0, 0), IN), (("C", 1), INOUT)]
    assert scoped_deps(None, deps) is deps          # identity: no scope
    wrapped = scoped_deps(3, deps)
    assert wrapped == ((ScopedRegion(3, ("A", 0, 0)), IN),
                       (ScopedRegion(3, ("C", 1)), INOUT))
    # two scopes touching the same app region produce distinct keys
    # (no false dependence possible) AND distinct shard hashes
    r1 = ScopedRegion(1, ("A", 0, 0))
    r2 = ScopedRegion(2, ("A", 0, 0))
    assert r1 != r2
    assert stable_region_hash(r1) != stable_region_hash(r2)


def test_wd_inherits_scope_from_parent():
    root = WorkDescriptor(func=None, label="r", scope=9)
    child = WorkDescriptor(func=None, label="c", parent=root)
    grand = WorkDescriptor(func=None, label="g", parent=child)
    assert child.scope == 9 and grand.scope == 9
    stranger = WorkDescriptor(func=None, label="s")
    assert stranger.scope is None


def test_scope_task_regions_are_scope_qualified():
    with TaskRuntime(num_workers=1, mode="sync", num_clients=1) as rt:
        sc = rt.open_scope("t")
        wd = sc.task(lambda: None, deps=[(("A",), "inout")])
        sc.taskwait()
        assert wd.deps[0][0] == ScopedRegion(sc.scope_id, ("A",))
        assert wd.scope == sc.scope_id


# ------------------------------------------------------ API contract
def test_open_scope_requires_clients():
    with TaskRuntime(num_workers=1, mode="sync") as rt:
        with pytest.raises(ValueError, match="num_clients"):
            rt.open_scope("nope")


def test_scope_parameter_validation():
    with TaskRuntime(num_workers=1, mode="sync", num_clients=1) as rt:
        with pytest.raises(ValueError):
            rt.open_scope("w", weight=0.0)
        with pytest.raises(ValueError):
            rt.open_scope("c", max_inflight=0)


def test_client_slot_exhaustion():
    with TaskRuntime(num_workers=1, mode="sync", num_clients=1) as rt:
        errs = []
        # both threads stay alive through both attempts: a dead client
        # thread's ident (and with it its slot) may be reused, which is
        # fine for SPSC safety but not what this test is about
        attempted = threading.Barrier(2)

        def client():
            try:
                rt.open_scope("x")
            except RuntimeError as e:
                errs.append(e)
            attempted.wait()

        ts = [threading.Thread(target=client) for _ in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(errs) == 1           # one slot, two LIVE clients


def test_client_slots_recycled_after_scope_close():
    """Tenant-session churn (thread per session) must be bounded by
    CONCURRENT clients, not total ones: a thread's submit slot returns
    to the pool when its last scope closes."""
    with TaskRuntime(num_workers=1, mode="sync", num_clients=1) as rt:
        for k in range(3):              # 3 sessions, 1 client slot
            def session(k=k):
                sc = rt.open_scope(f"s{k}")
                sc.task(_spin, deps=[((0,), "inout")])
                sc.close()

            t = threading.Thread(target=session)
            t.start()
            t.join()
        assert len(rt._free_client_slots) == 1


def test_run_scopes_validation():
    sim = RuntimeSimulator(2, "sync")
    with pytest.raises(ValueError):
        sim.run_scopes([])
    with pytest.raises(ValueError):
        sim.run_scopes([[SimTaskSpec(dur=1.0)]] * 3)    # 3 scopes, 2 cores
    with pytest.raises(ValueError):
        RuntimeSimulator(2, "dast").run_scopes(
            [[SimTaskSpec(dur=1.0)]] * 2)               # mgr core reserved
    with pytest.raises(ValueError):
        sim.run_scopes([[SimTaskSpec(dur=1.0)]], weights=[1.0, 2.0])


# ------------------------------------------- scope isolation oracle
@pytest.mark.parametrize("mode", ALL_MODES)
def test_sim_scope_isolation_oracle(mode):
    """Concurrent matmul + sparse-LU scopes: each scope's execution
    respects exactly the dependence ordering of its solo run, for every
    policy, and the rollups attribute every task to its scope."""
    mm = _relabel(sim_app_specs("matmul", 3), "mm")
    lu = _relabel(sim_app_specs("sparselu", 5), "lu")
    r = RuntimeSimulator(4, mode).run_scopes([mm, lu], names=["mm", "lu"])
    assert r.tasks == len(mm) + len(lu)
    assert r.scopes["mm"]["tasks"] == len(mm)
    assert r.scopes["lu"]["tasks"] == len(lu)
    _check_scope_order(r, mm)
    _check_scope_order(r, lu)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_sim_scope_isolation_nested(mode):
    """A nested-task tenant (N-Body) next to a flat one."""
    nb = _relabel(sim_app_specs("nbody", 3), "nb")
    mm = _relabel(sim_app_specs("matmul", 3), "mm")
    r = RuntimeSimulator(4, mode).run_scopes([nb, mm], names=["nb", "mm"])
    assert r.tasks == r.scopes["nb"]["tasks"] + r.scopes["mm"]["tasks"]
    _check_scope_order(r, mm)
    _check_scope_order(r, nb)           # top-level timestep chain


@pytest.mark.parametrize("mode", ALL_MODES)
def test_threaded_scope_isolation_byte_identical(mode):
    """Two client threads, matmul + sparse-LU concurrently: per-scope
    results are byte-identical to each app run alone (per-scope
    dependence order fixes the float op order; the keying shim plus
    per-parent namespaces make cross-tenant interference impossible)."""
    refs = _solo_refs()
    outs = {}
    with TaskRuntime(num_workers=3, mode=mode, num_clients=2) as rt:
        def mm_client():
            with rt.open_scope("mm"):
                outs["mm"] = run_matmul(rt, refs["a"], refs["b"], bs=4)

        def lu_client():
            with rt.open_scope("lu"):
                outs["lu"] = run_sparselu(rt, refs["m"], bs=4)

        ts = [threading.Thread(target=mm_client),
              threading.Thread(target=lu_client)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert np.array_equal(outs["mm"], refs["mm"])
    assert np.array_equal(outs["lu"], refs["lu"])
    assert np.allclose(outs["lu"], sparselu_oracle(refs["m"], 4),
                       atol=2e-2)
    st = rt.stats.scopes
    assert st["mm"]["tasks"] == 4 ** 3
    assert st["lu"]["tasks"] > 0


# ------------------------------- per-scope replay: steady state
@pytest.mark.parametrize("mode", ALL_MODES)
def test_sim_two_scope_replay_steady_state(mode):
    """Acceptance: two scopes submitting structurally identical graphs
    concurrently each reach steady-state replay — iterations beyond the
    first add ZERO lock acquisitions and ZERO mailbox messages."""
    specs = [sim_app_specs("matmul", 3), sim_app_specs("matmul", 3)]
    r1 = RuntimeSimulator(6, mode, replay=True).run_scopes(
        specs, iterations=1)
    r4 = RuntimeSimulator(6, mode, replay=True).run_scopes(
        specs, iterations=4)
    assert r4.lock_acquisitions == r1.lock_acquisitions
    assert r4.messages == r1.messages
    for name in ("scope0", "scope1"):
        assert r4.scopes[name]["replay_iterations"] == 3
        assert r4.scopes[name]["tasks"] == 4 * 27


def _spin():
    x = 0.0
    for i in range(50):
        x += i * i
    return x


@pytest.mark.parametrize("mode", ALL_MODES)
def test_threaded_two_scope_replay_steady_state(mode):
    """Acceptance (real threads): after both tenants froze their
    recordings, further concurrent iterations perform zero graph-lock
    acquisitions and process zero mailbox messages."""
    iters, ntasks = 4, 30
    barrier = threading.Barrier(2)
    snap = []

    with TaskRuntime(num_workers=3, mode=mode, num_clients=2,
                     replay=True) as rt:
        def client(name):
            sc = rt.open_scope(name)
            for it in range(iters):
                for i in range(ntasks):
                    sc.task(_spin, deps=[((i % 7,), "inout")],
                            label=f"t{i}")
                sc.taskwait()
                barrier.wait()          # both tenants quiesced
                if name == "a" and it == 1:
                    st = rt.policy.stats()
                    snap.append((st["lock_acquisitions"],
                                 st["messages_processed"]))
                barrier.wait()
            sc.close()

        ts = [threading.Thread(target=client, args=(n,))
              for n in ("a", "b")]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        st = rt.policy.stats()
        final = (st["lock_acquisitions"], st["messages_processed"])
        assert final == snap[0], (mode, snap[0], final)
        for name in ("a", "b"):
            sc = next(s for s in rt._scopes if s.name == name)
            pol = rt.policy.scope_policy(sc.scope_id)
            assert pol.replay_iterations == iters - 1


def test_threaded_scope_divergence_is_isolated():
    """Tenant A diverging (different structure on iteration 2) must not
    disturb tenant B's steady-state replay."""
    count = {"a": 0, "b": 0}
    lock = threading.Lock()

    def bump(k):
        with lock:
            count[k] += 1

    with TaskRuntime(num_workers=2, mode="sync", num_clients=2,
                     replay=True) as rt:
        def client_a():
            sc = rt.open_scope("a")
            for it in range(4):
                if it == 1:             # structural divergence
                    for i in range(5):
                        sc.task(bump, "a", deps=[(("x", i), "inout")])
                else:
                    for i in range(8):
                        sc.task(bump, "a", deps=[((i % 3,), "inout")])
                sc.taskwait()
            sc.close()

        def client_b():
            sc = rt.open_scope("b")
            for _ in range(4):
                for i in range(8):
                    sc.task(bump, "b", deps=[((i % 3,), "inout")])
                sc.taskwait()
            sc.close()

        ts = [threading.Thread(target=client_a),
              threading.Thread(target=client_b)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        pol_a = rt.policy.scope_policy(rt._scopes[0].scope_id) \
            if rt._scopes[0].name == "a" else \
            rt.policy.scope_policy(rt._scopes[1].scope_id)
        pol_b = rt.policy.scope_policy(
            next(s.scope_id for s in rt._scopes if s.name == "b"))
        assert isinstance(pol_a, ReplayPolicy)
        assert pol_a.invalidations >= 1
        assert pol_b.invalidations == 0
        assert pol_b.replay_iterations == 3
    assert count == {"a": 8 + 5 + 8 + 8, "b": 32}


def test_scope_taskwait_not_blocked_by_other_tenant_backlog():
    """A tenant's taskwait gates on ITS OWN subtree: another tenant's
    un-flushed submit buffers (global pending > 0) must not delay it."""
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     batch_size=8, num_clients=2) as rt:
        release = threading.Event()
        parked = threading.Event()
        done = []

        def b_client():
            sb = rt.open_scope("b")
            for i in range(3):          # < batch_size: stays buffered
                sb.task(_spin, deps=[((i,), "inout")])
            parked.set()
            release.wait()              # holds its backlog un-flushed
            sb.close()

        def a_client():
            sa = rt.open_scope("a")
            sa.task(_spin, deps=[((0,), "inout")])
            sa.taskwait()               # must return despite B's backlog
            done.append(True)
            sa.close()

        tb = threading.Thread(target=b_client)
        tb.start()
        parked.wait()
        assert rt._pending_msgs() > 0   # B's buffer really is pending
        ta = threading.Thread(target=a_client)
        ta.start()
        ta.join(timeout=20)
        assert done, "scope A's taskwait blocked on scope B's backlog"
        release.set()
        tb.join()


def test_shutdown_drains_abandoned_scope_with_buffered_submits():
    """A client thread that submits (into its slot's batch buffer) and
    departs without taskwait must not wedge shutdown: scope-root
    taskwaits flush EVERY slot, so the orphaned buffer ships."""
    done = []

    def drive():
        with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                         batch_size=8, num_clients=1) as rt:
            def rude_client():
                sc = rt.open_scope("rude")
                for i in range(2):      # < batch_size: stays buffered
                    sc.task(_spin, deps=[((i,), "inout")])
                # departs without taskwait/close

            t = threading.Thread(target=rude_client)
            t.start()
            t.join()
        done.append(rt.stats.tasks_executed)

    driver = threading.Thread(target=drive, daemon=True)
    driver.start()
    driver.join(timeout=30)
    assert done, "shutdown hung on the abandoned scope's buffer"
    assert done[0] == 2


# ----------------------------------------------- fair admission layer
def test_fair_admission_weighted_grants():
    """2:1 weights get 2:1 ± 25% of the execution prefix while both
    tenants are backlogged (the bench_scopes CI gate, in miniature)."""
    def flood(n, tag):
        return [SimTaskSpec(dur=100.0, deps=[((tag, i), INOUT)],
                            label=f"{tag}.{i}") for i in range(n)]

    r = RuntimeSimulator(4, "sync").run_scopes(
        [flood(90, "a"), flood(90, "b")], weights=[2.0, 1.0],
        names=["a", "b"])
    pre = r.exec_order[:90]             # both still backlogged here
    na = sum(1 for l in pre if l.startswith("a."))
    nb = len(pre) - na
    assert 1.5 <= na / nb <= 2.5, (na, nb)


def test_fair_admission_backpressure_cap():
    inner = RoundRobinPlacement(2)
    fa = FairAdmission(inner, window=100)
    fa.register_scope(1, weight=1.0, max_inflight=2)
    wds = [WorkDescriptor(func=None, label=f"t{i}", scope=1)
           for i in range(10)]
    for wd in wds:
        fa.push(wd)
    # at most max_inflight of the scope's tasks occupy the shared pool
    assert inner.ready_count() == 2
    assert fa.ready_count() == 10
    got = set()
    for _ in range(10):
        assert inner.ready_count() <= 2
        wd = fa.pop(0)
        assert wd is not None
        got.add(wd.label)
    assert fa.pop(0) is None
    assert got == {f"t{i}" for i in range(10)}
    adm = fa.scope_admission(1)
    assert adm["admitted"] == 10
    assert adm["admission_waits"] == 8  # tasks 3..10 each waited once
    assert adm["max_queued"] == 8       # ring high-water behind the cap


def test_fair_admission_window_backpressure():
    inner = RoundRobinPlacement(2)
    fa = FairAdmission(inner, window=3)
    fa.register_scope(1, weight=1.0)
    fa.register_scope(2, weight=1.0)
    for i in range(4):
        fa.push(WorkDescriptor(func=None, label=f"a{i}", scope=1))
        fa.push(WorkDescriptor(func=None, label=f"b{i}", scope=2))
    assert inner.ready_count() == 3     # shared window binds
    drained = 0
    while fa.pop(0) is not None:
        drained += 1
        assert inner.ready_count() <= 3
    assert drained == 8


def test_fair_admission_forwards_shard_rekey():
    """ShardedPolicy.resize re-keys a shard-affine placement through
    getattr(placement, 'set_num_shards') — the wrapper must not hide
    it."""
    from repro.core.sched.placement import ShardAffinePlacement
    inner = ShardAffinePlacement(2, num_shards=4)
    fa = FairAdmission(inner)
    fa.set_num_shards(8)
    assert inner._num_shards == 8


def test_fair_admission_default_context_bypasses_rings():
    inner = RoundRobinPlacement(2)
    fa = FairAdmission(inner, window=1)
    fa.register_scope(1, weight=1.0)
    wd = WorkDescriptor(func=None, label="root-task")   # scope None
    fa.push(wd)
    assert inner.ready_count() == 1     # straight through, no window
    assert fa.pop(0) is wd


# ------------------------------------------------- serve satellites
class _StubModel:
    """Just enough ModelAPI for the request layer: constant logits."""

    def init_cache(self, batch, max_len):
        return {}

    def decode_step(self, params, cache, tokens, pos):
        logits = jnp.zeros((tokens.shape[0], 16)).at[:, 7].set(1.0)
        return logits, cache


def test_serve_engines_number_requests_independently():
    from repro.serve.engine import Request, ServeEngine
    e1 = ServeEngine(_StubModel(), None, batch_slots=2, max_len=8,
                     num_clients=1)
    e2 = ServeEngine(_StubModel(), None, batch_slots=2, max_len=8,
                     num_clients=1)
    ids1 = [e1.submit(Request(prompt=[1], max_new_tokens=1)).req_id
            for _ in range(3)]
    ids2 = [e2.submit(Request(prompt=[1], max_new_tokens=1)).req_id
            for _ in range(3)]
    # a module-global counter would interleave these
    assert ids1 == [0, 1, 2]
    assert ids2 == [0, 1, 2]


def test_serve_engine_runtime_scopes():
    """Each client queue rides a JobScope on the real runtime: outputs
    unchanged, per-client fairness counters live in the scope layer."""
    from repro.serve.engine import Request, ServeEngine
    with TaskRuntime(num_workers=2, mode="ddast", num_clients=2) as rt:
        eng = ServeEngine(_StubModel(), None, batch_slots=2, max_len=8,
                          num_clients=2, runtime=rt,
                          client_weights=[2.0, 1.0])
        reqs = [eng.submit(Request(prompt=[1, 2], max_new_tokens=2),
                           i % 2) for i in range(6)]
        eng.run_until_drained()
        assert all(r.output == [7, 7] for r in reqs)
        adm = eng.scope_admission()
        assert adm["client0"]["admitted"] == 3
        assert adm["client1"]["admitted"] == 3
        assert adm["client0"]["weight"] == 2.0
    st = rt.stats.scopes
    assert st["client0"]["tasks"] == 3 and st["client1"]["tasks"] == 3


def test_serve_engine_stepped_from_dedicated_thread():
    """The serving thread differs from the constructing (main) thread:
    the pump must claim its own submit slot (one extra num_clients)
    rather than share the main slot's SPSC queue."""
    from repro.serve.engine import Request, ServeEngine
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     num_clients=3) as rt:
        eng = ServeEngine(_StubModel(), None, batch_slots=2, max_len=8,
                          num_clients=2, runtime=rt)
        reqs = [eng.submit(Request(prompt=[1], max_new_tokens=2), i % 2)
                for i in range(4)]
        server = threading.Thread(target=eng.run_until_drained)
        server.start()
        # the main thread keeps submitting default-context tasks
        # concurrently — distinct slots, so both streams survive
        for i in range(50):
            rt.task(_spin, deps=[((i % 5,), "inout")])
        rt.taskwait()
        server.join(timeout=30)
        assert not server.is_alive()
        assert all(r.output == [7, 7] for r in reqs)
