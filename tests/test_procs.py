"""Process backend (core.procs): the processes-vs-serial oracle on the
three paper apps (exact float equality — the kernels are
multiply-accumulate chains, so any dependence-ordering violation changes
the result), dependence-order verification from worker-stamped exec
spans, replay steady-state 0-message checks across the process boundary,
trace-ring merge schema agreement with the threaded driver, worker-death
and body-error propagation, shm-ring wraparound/fallback behavior, wire
codec roundtrips, SimCosts IPC knobs, and clean shutdown with no leaked
shared-memory segments."""
import os
import pickle
import signal

import pytest

from repro.core import (ProcessRuntime, ShmRing, SimCosts, TaskFailed,
                        TaskRuntime, WorkerLost)
from repro.core.engine.charge import SimCharger
from repro.core.messages import (DONE_ERROR, DONE_OK, decode_done_batch,
                                 decode_submit_batch, encode_done_batch,
                                 encode_submit_batch)
from repro.core.procs import apps
from repro.core.trace import EV_CREATED, EV_END, EV_START

PROC_MODES = ("sync", "dast", "ddast", "sharded")


def _drain(shms):
    for s in shms:
        s.close_unlink()


def _assert_no_leaks(rt):
    names = rt.shm_names()
    rt.shutdown()
    leaked = [n for n in names if os.path.exists("/dev/shm/" + n)]
    assert not leaked, f"leaked shm segments: {leaked}"


# ------------------------------------------------------------ oracles
def _oracle_matmul(mode, replay=False, iterations=1):
    N, bs = 3, 3
    A = apps.ShmArray((N * bs) ** 2)
    B = apps.ShmArray((N * bs) ** 2)
    C = apps.ShmArray((N * bs) ** 2)
    C2 = apps.ShmArray((N * bs) ** 2)
    apps.fill_deterministic(A, 3)
    apps.fill_deterministic(B, 5)
    try:
        rt = ProcessRuntime(num_workers=2, mode=mode, replay=replay)
        with rt:
            for _ in range(iterations):
                calls = apps.submit_matmul(rt, A.name, B.name, C.name,
                                           N, bs)
                rt.taskwait()
        for _ in range(iterations):
            apps.run_serial([(f, tuple([a[0], a[1], C2.name] + list(a[3:])),
                              d, l) for f, a, d, l in calls])
        assert C.tolist() == C2.tolist()
        return rt
    finally:
        _drain([A, B, C, C2])


@pytest.mark.parametrize("mode", ["sync", "sharded"])
def test_matmul_matches_serial(mode):
    rt = _oracle_matmul(mode)
    assert rt.stats.tasks_executed == 27


def test_sparselu_matches_serial():
    nb, bs = 4, 3
    M = apps.ShmArray(nb * nb * bs * bs)
    M2 = apps.ShmArray(nb * nb * bs * bs)
    apps.fill_deterministic(M, 11)
    apps.fill_deterministic(M2, 11)
    try:
        with ProcessRuntime(num_workers=2, mode="sharded") as rt:
            calls = apps.submit_sparselu(rt, M.name, nb, bs)
            rt.taskwait()
        apps.run_serial([(f, tuple([M2.name] + list(a[1:])), d, l)
                         for f, a, d, l in calls])
        assert M.tolist() == M2.tolist()
    finally:
        _drain([M, M2])


def test_nbody_matches_serial():
    n = 8
    arrs = [apps.ShmArray(n) for _ in range(6)]
    P, V, A, P2, V2, A2 = arrs
    apps.fill_deterministic(P, 2)
    apps.fill_deterministic(P2, 2)
    try:
        with ProcessRuntime(num_workers=2, mode="ddast") as rt:
            calls = apps.submit_nbody(rt, P.name, V.name, A.name, n,
                                      steps=2)
            rt.taskwait()
        apps.run_serial([(f, tuple([{P.name: P2.name, V.name: V2.name,
                                     A.name: A2.name}.get(x, x)
                                    for x in a]), d, l)
                         for f, a, d, l in calls])
        assert P.tolist() == P2.tolist()
        assert V.tolist() == V2.tolist()
    finally:
        _drain(arrs)


def test_dependence_order_from_exec_spans():
    """Worker-stamped exec spans must respect every region edge:
    pred.t_end <= succ.t_start (one monotonic clock across processes)."""
    n = 6
    P, V, A = (apps.ShmArray(n) for _ in range(3))
    apps.fill_deterministic(P, 4)
    try:
        wds = []
        with ProcessRuntime(num_workers=2, mode="sharded") as rt:
            all_pos = [(("P", j), "in") for j in range(n)]
            for s in range(2):
                for i in range(n):
                    wds.append(rt.task(
                        apps.nbody_force, P.name, A.name, n, i,
                        deps=all_pos + [(("A", i), "out")],
                        label=f"force[{s},{i}]"))
                for i in range(n):
                    wds.append(rt.task(
                        apps.nbody_update, P.name, V.name, A.name, i,
                        deps=[(("A", i), "in"), (("V", i), "inout"),
                              (("P", i), "inout")],
                        label=f"update[{s},{i}]"))
            rt.taskwait()
        span = {wd.label: wd.exec_span for wd in wds}
        for s in range(2):
            for i in range(n):
                force_end = span[f"force[{s},{i}]"][1]
                upd_start = span[f"update[{s},{i}]"][0]
                assert force_end <= upd_start
                if s:
                    # update[s-1, j] writes P[j], force[s, i] reads all P
                    for j in range(n):
                        assert span[f"update[{s-1},{j}]"][1] <= \
                            span[f"force[{s},{i}]"][0]
    finally:
        _drain([P, V, A])


# ------------------------------------------------------------ replay
def test_replay_steady_state_zero_ipc():
    A = apps.ShmArray(8)
    apps.fill_deterministic(A, 9)
    ref = apps.ShmArray(8)
    apps.fill_deterministic(ref, 9)
    iters = 6
    try:
        with ProcessRuntime(num_workers=2, mode="sharded",
                            replay=True) as rt:
            for _ in range(iters):
                calls = []
                for i in range(10):
                    args = (A.name, A.name, A.name, i % 4)
                    calls.append((apps.nbody_update, args, None, None))
                    rt.task(apps.nbody_update, *args,
                            deps=[(("X", i % 4), "inout")], label=f"t{i}")
                rt.taskwait()
        # iteration 0 records (live mailbox traffic); every later
        # iteration runs on the shared replay plane: 0 Submit/Done
        # frames cross the process boundary
        assert rt.iter_ipc[0][0] > 0
        for sub, done in rt.iter_ipc[1:iters]:
            assert (sub, done) == (0, 0)
        assert rt.stats.replay_iterations >= iters - 2
        # and the data plane stayed correct through the replays
        for _ in range(iters):
            apps.run_serial([(f, (ref.name, ref.name, ref.name, a[3]),
                              None, None) for f, a, _d, _l in calls])
        assert A.tolist() == ref.tolist()
    finally:
        _drain([A, ref])


def test_replay_divergence_falls_back_live():
    A = apps.ShmArray(4)
    try:
        with ProcessRuntime(num_workers=1, mode="sharded",
                            replay=True) as rt:
            for it in range(4):
                n = 4 if it < 2 else 6      # structure changes at it=2
                for i in range(n):
                    rt.task(apps.nbody_update, A.name, A.name, A.name,
                            i % 2, deps=[(("X", i % 2), "inout")],
                            label=f"t{i}")
                rt.taskwait()
            assert rt.stats.tasks_executed == 4 + 4 + 6 + 6
    finally:
        _drain([A])


# ------------------------------------------------------------ traces
def test_trace_schema_agrees_with_threads():
    """Same workload, both drivers, trace=True: the merged event lists
    agree on the lifecycle multiset per label, worker events land on
    worker slots, and both are time-sorted."""
    def run(backend):
        A = apps.ShmArray(4)
        try:
            with TaskRuntime(num_workers=2, mode="sharded", trace=True,
                             backend=backend) as rt:
                for i in range(8):
                    rt.task(apps.nbody_update, A.name, A.name, A.name,
                            i % 2, deps=[(("X", i % 2), "inout")],
                            label=f"t{i}")
                rt.taskwait()
            return rt.stats.events
        finally:
            _drain([A])

    evs_t = run("threads")
    evs_p = run("processes")
    lifecycle = (EV_CREATED, EV_START, EV_END)

    def sig(evs):
        return sorted((e.label, e.ev) for e in evs
                      if e.ev in lifecycle and e.label.startswith("t"))

    assert sig(evs_t) == sig(evs_p)
    for evs in (evs_t, evs_p):
        assert [e.t for e in evs] == sorted(e.t for e in evs)
    # process-backend bodies run on worker slots (2 + widx)
    for e in evs_p:
        if e.ev in (EV_START, EV_END) and e.label.startswith("t"):
            assert e.slot >= 2


# ------------------------------------------------------------ failures
def _kill_self():
    os.kill(os.getpid(), signal.SIGKILL)


def _raise_value_error():
    raise ValueError("intentional kernel failure")


def test_worker_death_raises_worker_lost():
    rt = ProcessRuntime(num_workers=2, mode="sharded")
    rt.start()
    rt.task(_kill_self, label="victim")
    with pytest.raises(WorkerLost, match="victim"):
        rt.taskwait()
    rt.shutdown()                        # must not hang


def test_body_error_raises_task_failed():
    rt = ProcessRuntime(num_workers=1, mode="sync")
    rt.start()
    rt.task(_raise_value_error, label="bad")
    with pytest.raises(TaskFailed, match="intentional kernel failure"):
        rt.taskwait()
    rt.shutdown()


def test_unpicklable_task_rejected():
    with ProcessRuntime(num_workers=1) as rt:
        with pytest.raises(ValueError, match="picklable"):
            rt.task(lambda: None, label="lam")
        rt.taskwait()


# ------------------------------------------------------------ lifecycle
@pytest.mark.parametrize("mode", PROC_MODES)
def test_clean_shutdown_no_shm_leaks(mode):
    for _ in range(3):
        rt = ProcessRuntime(num_workers=2, mode=mode, replay=True)
        rt.start()
        for i in range(6):
            rt.task(apps.spin, 10.0, deps=[(("R", i % 2), "inout")],
                    label=f"s{i}")
        rt.taskwait()
        _assert_no_leaks(rt)


def test_results_round_trip():
    with ProcessRuntime(num_workers=1) as rt:
        wd = rt.task(sum, (1, 2, 3), label="sum")
        rt.taskwait()
        assert wd.result == 6


def test_backend_dispatch_and_validation():
    rt = TaskRuntime(num_workers=1, backend="processes")
    assert isinstance(rt, ProcessRuntime)
    rt.start()
    rt.shutdown()
    with pytest.raises(ValueError, match="backend"):
        TaskRuntime(backend="sidecars")
    with pytest.raises(TypeError):       # backend is keyword-only
        TaskRuntime(1, "sync", None, False, None, None, None,
                    "round_robin", False, 0, True, "processes")
    with pytest.raises(ValueError, match="scopes"):
        ProcessRuntime(num_clients=2)
    with pytest.raises(ValueError, match="mode"):
        ProcessRuntime(mode="warp")


# ------------------------------------------------------------ rings
def test_ring_wraparound():
    ring = ShmRing(capacity=256)
    try:
        payload = bytes(range(64))
        for _ in range(50):              # forces many wraps
            assert ring.try_push(payload)
            assert ring.pop() == payload
        assert ring.pop() is None
    finally:
        ring.close()
        ring.unlink()


def test_ring_fifo_and_backpressure():
    ring = ShmRing(capacity=256)
    try:
        frames = [bytes([i]) * 20 for i in range(14)]
        pushed = [f for f in frames if ring.try_push(f)]
        assert len(pushed) < len(frames)            # filled up
        assert ring.try_push(frames[0]) is False    # full: rejected
        assert [ring.pop() for _ in pushed] == pushed
    finally:
        ring.close()
        ring.unlink()


def test_ring_oversize_falls_back_in_order():
    import queue

    class FakeQueue:
        def __init__(self):
            self.q = queue.SimpleQueue()
        put = property(lambda s: s.q.put)
        get = property(lambda s: s.q.get)

    fb = FakeQueue()
    ring = ShmRing(capacity=256, fallback=fb)
    try:
        big = b"B" * 200                 # > capacity // 2: fallback lane
        ring.push(b"first")
        ring.push(big)
        ring.push(b"last")
        assert ring.pop() == b"first"
        assert ring.pop() == big         # FIFO preserved via marker
        assert ring.pop() == b"last"
        assert ring.fallbacks == 1
    finally:
        ring.close()
        ring.unlink()


def test_ring_attach_reads_header_capacity():
    ring = ShmRing(capacity=256)
    try:
        peer = ShmRing.attach(ring.name)
        # logical capacity comes from the header, never from shm.size
        # (page-rounded on some platforms)
        assert peer.capacity == ring.capacity == 256
        peer.close()
    finally:
        ring.close()
        ring.unlink()


def test_fallback_timeout_orphans_nothing():
    import queue

    fb = queue.SimpleQueue()
    ring = ShmRing(capacity=64, fallback=fb)
    try:
        while ring.try_push(b"x" * 8):   # 16-byte frames pack the ring
            pass                         # solid: no room for a marker
        big = b"B" * 60                  # oversize: fallback lane only
        assert ring._push_fallback(big, spin_s=0.01) is False
        assert fb.empty()                # timed out without enqueueing
        with pytest.raises(BufferError):
            ring.push(big, spin_s=0.01)  # retries may not double-enqueue
        assert fb.empty()
        assert ring.fallbacks == 0
    finally:
        ring.close()
        ring.unlink()


def test_push_waits_for_slow_but_live_consumer():
    ring = ShmRing(capacity=64)
    try:
        while ring.try_push(b"x" * 8):
            pass
        ring.consumer_alive = lambda: False
        with pytest.raises(BufferError):
            ring.push(b"y" * 8, spin_s=0.01)

        def probe():                     # live consumer making progress
            ring.pop()
            return True

        ring.consumer_alive = probe
        ring.push(b"y" * 8, spin_s=0.01)    # pre-fix: BufferError
        last = None
        while True:
            frame = ring.pop()
            if frame is None:
                break
            last = frame
        assert last == b"y" * 8
    finally:
        ring.close()
        ring.unlink()


# ------------------------------------------------------------ codecs
def test_wire_codec_roundtrips():
    sub = [(7, pickle.dumps((sum, ((1, 2),))), "alpha"),
           (2 ** 40, b"", "")]
    assert decode_submit_batch(encode_submit_batch(sub)) == sub
    done = [(7, 1.25, 2.5, DONE_OK, pickle.dumps(3)),
            (9, 0.0, 0.5, DONE_ERROR, "tb".encode())]
    assert decode_done_batch(encode_done_batch(done)) == done


# ------------------------------------------------------------ sim knobs
def test_sim_costs_ipc_knobs():
    costs = SimCosts(ipc_submit_us=5.0, ipc_done_us=3.0)
    ch = SimCharger(costs)
    ch.ipc_submit()
    ch.ipc_done()
    assert ch.now == pytest.approx(8.0)
    assert SimCosts().ipc_submit_us > 0
    assert SimCosts().ipc_done_us > 0
