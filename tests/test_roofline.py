"""Unit tests for the trip-count-aware HLO roofline parser."""
import jax.numpy as jnp

from repro.analysis.roofline import (RooflineTerms, _block_stats,
                                     _split_blocks, _trip_count,
                                     analyze_hlo, model_flops)
from repro.configs import get_config
from repro.models.config import get_shape

_HLO = """\
%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %w = f32[16,32]{1,0} constant(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%d), replica_groups={}
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %t = (s32[], f32[8,16]) tuple(%c0, %a)
  %wl = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
  %g = f32[64,16]{1,0} all-gather(%a), dimensions={0}
}
"""


def test_split_blocks_and_trip_count():
    blocks = _split_blocks(_HLO)
    assert set(blocks) >= {"body.1", "cond.1", "main"}
    assert _trip_count(blocks["cond.1"]) == 10


def test_dot_flops_with_symbol_table():
    blocks = _split_blocks(_HLO)
    st = _block_stats(blocks["body.1"])
    # dot [8,16]x[16,32]: 2*8*32*16 = 8192 flops
    assert st.dot_flops == 8192


def test_loop_multiplier_applied():
    terms = analyze_hlo(_HLO, devices=4)
    # body dot runs 10 times; per-device 81920, scaled x4 devices
    assert terms.flops == 8192 * 10 * 4
    # all-reduce inside loop: [8,32] f32 = 1024 B x 10 trips; gather once
    assert terms.coll_bytes["all-reduce"] == 1024 * 10 * 4
    assert terms.coll_bytes["all-gather"] == 64 * 16 * 4 * 4


def test_dominant_and_seconds():
    t = RooflineTerms(flops=197e12 * 256, hbm_bytes=0, coll_bytes={},
                      devices=256)
    assert t.seconds()["compute"] == 1.0
    assert t.dominant() == "compute"


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen2-72b")
    tr = model_flops(cfg, get_shape("train_4k"))
    de = model_flops(cfg, get_shape("decode_32k"))
    # train: 6*N*(256*4096 tokens); decode: 2*N*128 tokens
    assert tr / de == (6 * 256 * 4096) / (2 * 128)
