"""Unified dependence-policy engine (core.engine): the sim-vs-real
oracle (identical per-mode message counts and dependence orderings
through the shared policy objects), the policy-agnostic-driver check,
Submit batching, shard-affine placement (unit + property tests),
StealDeque concurrency stress, and online num_shards tuning."""
import os
import threading

import pytest

from repro.core import (DynamicTuner, RuntimeSimulator, TaskRuntime,
                        TunerConfig)
from repro.core.engine import (RoundRobinPlacement, ShardAffinePlacement,
                               make_placement, make_policy)
from repro.core.messages import SubmitBatchMessage
from repro.core.shards import ShardRouter, ShardedDependenceGraph, StealDeque
from repro.core.taskgraph_apps import sim_app_specs, sim_matmul_specs
from repro.core.wd import DepMode, TaskState, WorkDescriptor

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT

ALL_MODES = ("sync", "dast", "ddast", "sharded")


# ------------------------------------------------------------ helpers
def _run_specs_threaded(rt, specs, log=None):
    """Execute a SimTaskSpec graph on the real runtime (recursing into
    nested children exactly like the sim driver does). With `log`, each
    task body records (label, region, r/w) events under a lock."""
    lock = threading.Lock()

    def body(spec):
        if log is not None:
            with lock:
                for region, m in spec.deps:
                    log.setdefault(region, []).append(
                        (spec.label, "w" if m.writes else "r"))
        if spec.children:
            for ch in spec.children:
                rt.task(body, ch, deps=ch.deps, label=ch.label)
            rt.taskwait()

    for s in specs:
        rt.task(body, s, deps=s.deps, label=s.label)
    rt.taskwait()


def _submission_events(specs):
    """Per-region (label, r/w) events in submission order (flat graphs)."""
    events = {}
    for s in specs:
        for region, m in s.deps:
            events.setdefault(region, []).append(
                (s.label, "w" if m.writes else "r"))
    return events


def _check_region_order(events, sub_events):
    """Writers executed in submission order; every read saw the
    sequentially-correct last writer."""
    for region, evs in events.items():
        sub = sub_events[region]
        writes = [l for l, k in evs if k == "w"]
        assert writes == [l for l, k in sub if k == "w"], (region, evs)
        seq_last = {}
        cur = None
        for l, k in sub:
            if k == "w":
                cur = l
            else:
                seq_last[l] = cur
        cur = None
        for l, k in evs:
            if k == "w":
                cur = l
            else:
                assert cur == seq_last[l], (region, evs)


# ------------------------------------------------- the acceptance oracle
@pytest.mark.parametrize("mode", ALL_MODES)
@pytest.mark.parametrize("app,scale", [("matmul", 3), ("nbody", 3),
                                       ("sparselu", 5)])
def test_sim_and_real_share_policy_protocol(app, scale, mode):
    """TaskRuntime and RuntimeSimulator drive the SAME policy objects, so
    per-mode message counts must be identical on every app graph, and the
    real execution must respect the dependence ordering."""
    log = {}
    with TaskRuntime(num_workers=2, mode=mode, num_shards=8) as rt:
        _run_specs_threaded(rt, sim_app_specs(app, scale), log=log)
    sim = RuntimeSimulator(3, mode, num_shards=8).run(
        sim_app_specs(app, scale))
    assert rt.stats.tasks_executed == sim.tasks
    assert rt.stats.messages_processed == sim.messages
    # delegated_portions is structural (every portion that traversed a
    # shard request list), so the two drivers must agree exactly
    assert rt.stats.delegated_portions == sim.delegated_portions
    if mode == "sharded":
        assert sim.delegated_portions == sim.messages > 0
    assert len(sim.exec_order) == sim.tasks
    if app != "nbody":                  # flat graphs: full ordering check
        specs = sim_app_specs(app, scale)
        _check_region_order(log, _submission_events(specs))
        # and the simulated execution order respects the same protocol
        pos = {label: i for i, label in enumerate(sim.exec_order)}
        sim_events = {
            r: sorted(evs, key=lambda e: pos[e[0]])
            for r, evs in _submission_events(specs).items()}
        _check_region_order(sim_events, _submission_events(specs))


def test_runtime_driver_is_policy_agnostic():
    """The acceptance grep: no `mode ==` (nor placement-kind) branching
    left in either driver — the thread driver and the simulator delegate
    everything to the policy/placement registries."""
    import repro.core.runtime as rt_mod
    import repro.core.simulator as sim_mod
    for mod in (rt_mod, sim_mod):
        src = open(os.path.abspath(
            mod.__file__.replace(".pyc", ".py"))).read()
        assert "mode ==" not in src, mod.__name__
        assert "mode in (" not in src, mod.__name__
        assert "placement ==" not in src, mod.__name__
        assert "placement_kind ==" not in src, mod.__name__


@pytest.mark.parametrize("mode", ALL_MODES)
def test_policy_objects_are_shared_classes(mode):
    """Both drivers instantiate the same policy class from the same
    factory."""
    rt = TaskRuntime(num_workers=2, mode=mode)
    pol = make_policy(mode, 3, num_shards=4)
    assert type(rt.policy) is type(pol)


# ------------------------------------------------------- submit batching
def test_batched_submit_fewer_messages_same_result():
    specs = sim_app_specs("matmul", 4)
    base = RuntimeSimulator(4, "sharded", num_shards=16).run(specs)
    batched = RuntimeSimulator(4, "sharded", num_shards=16,
                               batch_size=8).run(
        sim_app_specs("matmul", 4))
    assert batched.tasks == base.tasks
    assert batched.messages < base.messages


def test_batched_threaded_matches_unbatched_order():
    specs = sim_app_specs("sparselu", 5)
    log = {}
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=8,
                     batch_size=4) as rt:
        _run_specs_threaded(rt, specs, log=log)
    assert rt.stats.tasks_executed == len(specs)
    _check_region_order(log, _submission_events(specs))
    # batch entries undercut one-message-per-portion routing: the done
    # side still costs one entry per shard portion, the submit side at
    # most that (usually far fewer).
    from repro.core.shards import stable_region_hash
    portions = sum(len({stable_region_hash(r) % 8 for r, _ in s.deps})
                   for s in specs)
    assert rt.stats.messages_processed <= 2 * portions


def test_submit_batch_message_processed_under_one_entry():
    """A batch of k chained tasks on one shard costs ONE mailbox entry
    and preserves submission order within the batch. Pins the blocking
    mailbox baseline (delegation=False): under delegation the publisher
    combines eagerly, so nothing ever sits in a mailbox to count."""
    graph = ShardedDependenceGraph(num_shards=1)
    ready = []
    router = ShardRouter(graph, on_ready=ready.append, delegation=False)
    root = WorkDescriptor(func=None, label="root")
    wds = [WorkDescriptor(func=None, deps=((("r",), INOUT),), parent=root)
           for _ in range(5)]
    for wd in wds:
        assert not router.prepare_submit(wd)
    router.push_batch(wds)
    assert router.pending() == 1
    assert router.drain_all() == 1
    assert router.messages_processed == 1
    # only the chain head is ready; the rest wait in submission order
    assert ready == [wds[0]]
    for i, wd in enumerate(wds):
        router.route_done(wd)
        router.drain_all()
        assert wd.state == TaskState.COMPLETED
        if i + 1 < len(wds):
            assert ready[-1] is wds[i + 1]
    assert graph.in_graph == 0


def test_taskwait_flushes_partial_batches():
    """A batch smaller than batch_size must still drain at taskwait."""
    with TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     batch_size=64) as rt:
        done = []
        for i in range(5):              # far fewer than batch_size
            rt.task(done.append, i, deps=[(("r", i), INOUT)])
        rt.taskwait()
        assert sorted(done) == list(range(5))
    assert rt.stats.tasks_executed == 5


def test_concurrent_drain_all_does_not_lose_buffered_submits():
    """Regression: drain_all flushing another thread's submit buffer must
    not race the owner's append (a lost WD would hang taskwait). One
    producer thread batches 3000 tasks while another hammers drain_all."""
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4,
                     batch_size=16)
    pol = rt.policy
    N = 3000
    stop = threading.Event()

    def drainer():
        while not stop.is_set():
            pol.drain_all()

    t = threading.Thread(target=drainer)
    t.start()
    try:
        for i in range(N):
            wd = WorkDescriptor(func=None, deps=(((i % 37,), INOUT),),
                                parent=rt._root)
            pol.submit(wd, 0)
    finally:
        stop.set()
        t.join(timeout=10.0)
    pol.drain_all()
    assert pol.pending() == 0
    # every submit portion shipped: nothing stranded in an orphaned list
    assert pol.stats()["messages_processed"] >= N // 16
    assert pol.in_graph() == N          # all inserted, none lost


def test_affinity_map_is_bounded():
    p = ShardAffinePlacement(2, max_regions=8)
    for i in range(100):
        p.note_executed(WorkDescriptor(func=None, deps=(((i,), IN),)), i % 2)
    assert len(p._affinity) == 8
    # most-recent region survives, oldest evicted
    wd = WorkDescriptor(func=None, deps=(((99,), IN),))
    assert p.preferred_slot(wd) == 99 % 2
    assert p.preferred_slot(
        WorkDescriptor(func=None, deps=(((0,), IN),))) is None


def test_batched_dependence_free_tasks_charged_like_unbatched():
    """Cost-model parity: N dependence-free tasks must price identically
    with and without batching (no phantom batching win)."""
    from repro.core import SimTaskSpec
    specs = [SimTaskSpec(dur=50.0, deps=(), label=f"f{i}")
             for i in range(40)]
    a = RuntimeSimulator(4, "sharded", num_shards=8).run(list(specs))
    b = RuntimeSimulator(4, "sharded", num_shards=8,
                         batch_size=8).run(list(specs))
    assert a.makespan_us == b.makespan_us
    assert a.messages == b.messages == 0


# ------------------------------------------------- shard-affine placement
def test_shard_affine_prefers_last_toucher():
    p = ShardAffinePlacement(4)
    a = WorkDescriptor(func=None, deps=((("r", 1), INOUT),))
    b = WorkDescriptor(func=None, deps=((("r", 1), INOUT),))
    p.note_executed(a, 2)
    p.push(b)
    assert len(p.deques[2]) == 1 and p.affine_pushes == 1
    assert p.pop(2) is b


def test_shard_affine_fallback_round_robin():
    p = ShardAffinePlacement(3)
    wds = [WorkDescriptor(func=None, deps=((("x", i), IN),))
           for i in range(6)]
    for wd in wds:
        p.push(wd)                      # no affinity known: round-robin
    assert p.fallback_pushes == 6 and p.affine_pushes == 0
    assert [len(d) for d in p.deques] == [2, 2, 2]


def test_make_placement_kinds():
    assert isinstance(make_placement("round_robin", 2), RoundRobinPlacement)
    assert isinstance(make_placement("shard_affine", 2),
                      ShardAffinePlacement)
    pre = ShardAffinePlacement(5)
    assert make_placement(pre, 5) is pre
    with pytest.raises(ValueError):
        make_placement(pre, 3)          # slot-count mismatch rejected
    with pytest.raises(ValueError):
        make_placement("nope", 2)


def test_shard_affine_end_to_end_correct():
    import numpy as np
    from repro.core.taskgraph_apps import run_matmul
    a = np.random.RandomState(3).rand(64, 64).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="sharded",
                     placement="shard_affine") as rt:
        c = run_matmul(rt, a, a, bs=16)
    np.testing.assert_allclose(c, a @ a, rtol=1e-4, atol=1e-4)
    pl = rt.placement
    assert pl.affine_pushes > 0         # locality path actually exercised


def test_shard_affine_in_simulator_deterministic():
    r1 = RuntimeSimulator(8, "sharded", placement="shard_affine").run(
        sim_matmul_specs(5, dur_us=50))
    r2 = RuntimeSimulator(8, "sharded", placement="shard_affine").run(
        sim_matmul_specs(5, dur_us=50))
    assert (r1.makespan_us, r1.messages) == (r2.makespan_us, r2.messages)
    assert r1.tasks == 125


# ---------------------------------------------- StealDeque under threads
def test_steal_deque_stress_no_loss_no_duplication():
    """Owner pops LIFO while 4 thieves steal FIFO: every pushed item is
    consumed exactly once."""
    d = StealDeque()
    N = 20_000
    out_lock = threading.Lock()
    consumed = []
    stop = threading.Event()

    def thief():
        got = []
        while not stop.is_set() or len(d):
            item = d.steal()
            if item is not None:
                got.append(item)
        with out_lock:
            consumed.extend(got)

    thieves = [threading.Thread(target=thief) for _ in range(4)]
    for t in thieves:
        t.start()
    got_owner = []
    for i in range(N):
        d.push(i)
        if i % 3 == 0:                  # owner pops from the hot end
            item = d.pop()
            if item is not None:
                got_owner.append(item)
    stop.set()
    for t in thieves:
        t.join(timeout=10.0)
    with out_lock:
        consumed.extend(got_owner)
    assert len(d) == 0
    assert len(consumed) == N, f"lost/dup: {len(consumed)} != {N}"
    assert sorted(consumed) == list(range(N))
    assert d.pushed == N and d.popped + d.stolen == N


# ------------------------------------------------- online shard tuning
def _quiesced_rt(num_shards=4, delegation=True):
    # the fabricated-stats tuner tests pin delegation=False: they drive
    # the blocking lock-wait metric branch (the delegation/handoffs
    # branch is exercised in test_delegation.py)
    return TaskRuntime(num_workers=2, mode="sharded", num_shards=num_shards,
                       delegation=delegation)


def test_sharded_policy_resize_at_quiescence():
    rt = _quiesced_rt(4)
    pol = rt.policy
    for i in range(12):
        rt.task(lambda: None, deps=[((i % 4,), INOUT)])
    assert not pol.resize(8)            # pending work: refused
    pol.drain_all()
    assert not pol.resize(8)            # in graph (not completed): refused
    # finish everything through the real path
    while True:
        wd = rt.placement.pop(rt.num_workers)
        if wd is None and not pol.pending() and not pol.in_graph():
            break
        if wd is not None:
            wd.mark_finished()
            pol.complete(wd, rt.num_workers)
        pol.drain_all()
    before = pol.stats()["messages_processed"]
    assert pol.resize(8)
    assert pol.num_shards == 8 and len(pol.router.mailboxes) == 8
    # cumulative counters carried across the swap
    assert pol.stats()["messages_processed"] == before
    # runtime still correct after the resize
    for i in range(6):
        rt.task(lambda: None, deps=[((i % 3,), INOUT)])
    pol.drain_all()
    assert rt.ready_count() == 3


def test_shard_tuner_hill_climb_converges():
    """Feed the controller fabricated stats: improving while doubling,
    then worsening — it must reverse once, then settle (bracketed)."""
    rt = _quiesced_rt(4, delegation=False)
    tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0,
                                         shard_min_messages=10))
    wait = [0.0]
    msgs = [0]

    def feed(metric_per_msg, n=100):
        msgs[0] += n
        wait[0] += metric_per_msg * n
        return {"messages_processed": msgs[0], "lock_wait_s": wait[0]}

    assert tuner.consider_shard_step(feed(1.0))      # first sample: 4->8
    assert rt.policy.num_shards == 8
    assert tuner.consider_shard_step(feed(0.5))      # better: 8->16
    assert rt.policy.num_shards == 16
    assert tuner.consider_shard_step(feed(0.9))      # worse: flip, 16->8
    assert rt.policy.num_shards == 8
    # worse again: bracketed -> one final step back to the best point,
    # then settled
    assert tuner.consider_shard_step(feed(1.5))
    assert tuner.shards_settled
    assert rt.policy.num_shards == 16
    assert not tuner.consider_shard_step(feed(0.1))  # settled: inert
    assert [n for _, n in tuner.shard_adjustments] == [8, 16, 8, 16]


def test_shard_tuner_does_not_oscillate_on_unimodal_metric():
    """Regression: a clean metric with an interior optimum must settle AT
    the optimum instead of bouncing S/2 -> S -> 2S forever."""
    rt = _quiesced_rt(8, delegation=False)
    tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0,
                                         shard_min_messages=10))
    cost = {2: 1.6, 4: 1.3, 8: 1.0, 16: 1.3, 32: 1.6}
    wait = [0.0]
    msgs = [0]
    for step in range(20):
        msgs[0] += 100
        wait[0] += cost[rt.policy.num_shards] * 100
        tuner.consider_shard_step({"messages_processed": msgs[0],
                                   "lock_wait_s": wait[0]})
        if tuner.shards_settled:
            break
    assert tuner.shards_settled, "hill-climb never settled"
    assert step < 10
    assert rt.policy.num_shards == 8    # settled at the optimum


def test_sim_dast_single_core_rejected():
    with pytest.raises(ValueError):
        RuntimeSimulator(1, "dast")


def test_shard_tuner_end_to_end_still_correct():
    import numpy as np
    from repro.core.taskgraph_apps import run_matmul
    a = np.random.RandomState(1).rand(64, 64).astype(np.float32)
    with TaskRuntime(num_workers=3, mode="sharded", num_shards=2) as rt:
        DynamicTuner(rt, TunerConfig(interval_s=0.0, shard_min_messages=8))
        c = run_matmul(rt, a, a, bs=16)
        c2 = run_matmul(rt, a, a, bs=16)   # second phase after quiescence
    np.testing.assert_allclose(c, a @ a, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(c2, a @ a, rtol=1e-4, atol=1e-4)


# ------------------------------------- hypothesis property tests (guarded)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def affinity_scenario(draw):
        num_slots = draw(st.integers(2, 6))
        regions = draw(st.lists(st.integers(0, 9), min_size=1, max_size=4,
                                unique=True))
        known = draw(st.dictionaries(st.integers(0, 9),
                                     st.integers(0, num_slots - 1),
                                     max_size=6))
        return num_slots, regions, known

    @given(affinity_scenario())
    @settings(max_examples=50, deadline=None)
    def test_property_affine_placement(scenario):
        """Affinity respected when a preferred deque exists; round-robin
        fallback otherwise — and the task is always retrievable."""
        num_slots, regions, known = scenario
        p = ShardAffinePlacement(num_slots)
        for region, slot in known.items():
            p.note_executed(
                WorkDescriptor(func=None, deps=((region, IN),)), slot)
        wd = WorkDescriptor(func=None,
                            deps=tuple((r, INOUT) for r in regions))
        expected = next((known[r] for r in regions if r in known), None)
        p.push(wd)
        if expected is not None:
            assert len(p.deques[expected]) == 1
            assert p.affine_pushes == 1
        else:
            assert p.fallback_pushes == 1
        assert p.pop(0) is wd           # reachable from any slot (steal)

    @given(st.lists(st.integers(0, 7), min_size=1, max_size=30),
           st.integers(1, 6))
    @settings(max_examples=30, deadline=None)
    def test_property_batched_router_counts_balance(region_ids, batch):
        """Random chains through the batched router: every task completes
        and the graph empties (latch arithmetic balances)."""
        graph = ShardedDependenceGraph(num_shards=4)
        ready = []
        router = ShardRouter(graph, on_ready=ready.append)
        root = WorkDescriptor(func=None, label="root")
        wds, buf = [], []
        for rid in region_ids:
            wd = WorkDescriptor(func=None, deps=(((rid,), INOUT),),
                                parent=root)
            wds.append(wd)
            if not router.prepare_submit(wd):
                buf.append(wd)
            if len(buf) >= batch:
                router.push_batch(buf)
                buf = []
        if buf:
            router.push_batch(buf)
        router.drain_all()
        while any(wd.state != TaskState.COMPLETED for wd in wds):
            for wd in list(ready):
                if wd.state == TaskState.READY:
                    wd.mark_finished()
                    router.route_done(wd)
            router.drain_all()
        assert graph.in_graph == 0
