"""Fault tolerance (core.procs chaos + threaded scopes): deterministic
fault injection through :class:`FaultPlan` — worker kills mid-run with
retry-to-completion checked serial-exact against an idempotent
ping-pong oracle, seeded kill soaks across policy modes, fail-fast
``retries=0`` semantics, body-error retry/poison with attempt history,
timeout kills (recovered and poisoned), dropped/delayed done frames,
CRC-guarded ring frames and corrupt-frame worker respawn, shutdown
escalation to SIGKILL for SIGTERM-ignoring zombies, shm leak scans —
plus the threaded side: per-scope failure isolation, scope deadlines
and budgets (ScopeExpired + drain counts), threaded retries, and fault
events in the trace."""
import os
import time

import pytest

from repro.core import (FaultPlan, ProcessRuntime, RingCorruption,
                        ScopeExpired, ShmRing, TaskFailed, TaskRuntime,
                        WorkerLost)
from repro.core.procs import apps
from repro.core.trace import (EV_RESPAWN, EV_RETRY, EV_SCOPE_EXPIRED,
                              EV_TIMEOUT_KILL, EV_TRACE_LOST,
                              EV_WORKER_LOST)


# ------------------------------------------------------------ oracle app
#
# Idempotent ping-pong stencil: generation g reads buffer g%2 and
# assigns (never accumulates into) its own cell of buffer (g+1)%2, so a
# re-executed body recomputes the identical value from inputs that the
# dependence edges pin in place until every reader finished — the
# at-least-once retry contract the README documents. Regions key the
# PHYSICAL cells (buffer index, i), so the generation-(g+2) writer of
# the same cell carries WAW/WAR edges behind generation-g's write and
# its readers.

def _pp_step(n0, n1, n, g, i, spin_us=0.0):
    bufs = (apps._attach(n0), apps._attach(n1))
    if spin_us:
        apps.spin(spin_us)
    src, dst = bufs[g % 2], bufs[(g + 1) % 2]
    dst[i] = (src[(i - 1) % n] + src[i] + src[(i + 1) % n]) * 0.5 + 1.0


def _pp_deps(n, g, i):
    return [(("cell", (g + 1) % 2, i), "inout"),
            (("cell", g % 2, (i - 1) % n), "in"),
            (("cell", g % 2, i), "in"),
            (("cell", g % 2, (i + 1) % n), "in")]


def _submit_pingpong(rt, n0, n1, n, g0, stages, retries=0, timeout=None,
                     spin_us=0.0):
    for g in range(g0, g0 + stages):
        for i in range(n):
            rt.task(_pp_step, n0, n1, n, g, i, spin_us,
                    deps=_pp_deps(n, g, i), label=f"pp[{g},{i}]",
                    retries=retries, timeout=timeout)


def _serial_pingpong(init, n, stages):
    bufs = [list(init), [0.0] * n]
    for g in range(stages):
        src, dst = bufs[g % 2], bufs[(g + 1) % 2]
        for i in range(n):
            dst[i] = (src[(i - 1) % n] + src[i] + src[(i + 1) % n]) \
                * 0.5 + 1.0
    return bufs[stages % 2]


def _pingpong_arrays(n, seed=7):
    b0, b1 = apps.ShmArray(n), apps.ShmArray(n)
    apps.fill_deterministic(b0, seed)
    return b0, b1


def _drain(shms):
    for s in shms:
        s.close_unlink()


# ------------------------------------------------------------ kill+retry
def test_kill_and_retry_serial_exact():
    n, stages = 6, 4
    b0, b1 = _pingpong_arrays(n)
    init = b0.tolist()
    try:
        plan = FaultPlan().kill_worker(1, after_tasks=5)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan) as rt:
            _submit_pingpong(rt, b0.name, b1.name, n, 0, stages,
                             retries=2, spin_us=300.0)
            rt.taskwait()
        assert (b0.tolist() if stages % 2 == 0 else b1.tolist()) \
            == _serial_pingpong(init, n, stages)
        assert rt.stats.worker_respawns >= 1
        assert rt.stats.tasks_executed == n * stages
        assert rt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])


def test_retries_zero_fail_fast():
    n = 6
    b0, b1 = _pingpong_arrays(n)
    try:
        plan = FaultPlan().kill_worker(0, after_tasks=3)
        rt = ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan)
        rt.start()
        _submit_pingpong(rt, b0.name, b1.name, n, 0, 4, retries=0,
                         spin_us=500.0)
        with pytest.raises(WorkerLost):
            rt.taskwait()
        rt.shutdown()                    # must not hang or respawn
        assert rt.stats.worker_respawns == 0
        assert rt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])


@pytest.mark.parametrize("mode", ["sharded", "ddast"])
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_seeded_kill_soak(mode, seed):
    """The chaos soak: a seeded random kill plan (two kills at distinct
    shipped-task counts) must still produce the serial-exact answer with
    no leaked shm; a failing seed is a one-line repro."""
    n, stages = 6, 4
    b0, b1 = _pingpong_arrays(n, seed=seed)
    init = b0.tolist()
    try:
        plan = FaultPlan.seeded_kills(seed, num_workers=2,
                                      total_tasks=n * stages, kills=2)
        with ProcessRuntime(num_workers=2, mode=mode, ipc_batch=1,
                            fault_plan=plan) as rt:
            _submit_pingpong(rt, b0.name, b1.name, n, 0, stages,
                             retries=3, spin_us=300.0)
            rt.taskwait()
        assert b0.tolist() == _serial_pingpong(init, n, stages)
        assert rt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])


def test_seeded_kills_deterministic():
    a = FaultPlan.seeded_kills(42, 4, 100)
    b = FaultPlan.seeded_kills(42, 4, 100)
    c = FaultPlan.seeded_kills(43, 4, 100)
    sig = lambda p: [(e[0], e[1]) for e in p._kills]
    assert sig(a) == sig(b)
    assert sig(a) != sig(c)


# ------------------------------------------------------------ body errors
def _flaky_once(flag_name, out_name, i):
    F, O = apps._attach(flag_name), apps._attach(out_name)
    if F[i] == 0.0:
        F[i] = 1.0
        raise RuntimeError("transient failure")
    O[i] = i + 1.0


def _always_fails():
    raise ValueError("permanent failure")


def test_body_error_retried_then_succeeds():
    flag, out = apps.ShmArray(4), apps.ShmArray(4)
    try:
        with ProcessRuntime(num_workers=2, mode="sync") as rt:
            for i in range(4):
                rt.task(_flaky_once, flag.name, out.name, i,
                        label=f"flaky{i}", retries=1)
            rt.taskwait()
        assert out.tolist() == [1.0, 2.0, 3.0, 4.0]
        assert rt.stats.task_retries == 4
        assert rt.stats.tasks_poisoned == 0
    finally:
        _drain([flag, out])


def test_body_error_poisoned_with_attempt_history():
    rt = ProcessRuntime(num_workers=1, mode="sync")
    rt.start()
    rt.task(_always_fails, label="doomed", retries=2)
    with pytest.raises(TaskFailed, match="permanent failure") as ei:
        rt.taskwait()
    rt.shutdown()
    (label, tb, attempts), = ei.value.failures
    assert label == "doomed"
    assert "permanent failure" in tb
    assert len(attempts) == 2            # two retries before poisoning
    assert all(a["reason"] == "error" for a in attempts)
    assert rt.stats.tasks_poisoned == 1
    assert rt.stats.task_retries == 2


# ------------------------------------------------------------ timeouts
def _stall_once_then_write(flag_name, out_name, i):
    F, O = apps._attach(flag_name), apps._attach(out_name)
    if F[i] == 0.0:
        F[i] = 1.0                       # first attempt only: wedge
        time.sleep(5.0)                  # killed by the timeout scan
    O[i] = i + 1.0


def test_timeout_kill_then_retry_succeeds():
    flag, out = apps.ShmArray(2), apps.ShmArray(2)
    try:
        with ProcessRuntime(num_workers=2, mode="sharded",
                            ipc_batch=1) as rt:
            rt.task(_stall_once_then_write, flag.name, out.name, 0,
                    label="stuck", retries=1, timeout=0.3)
            rt.task(_flaky_write, out.name, 1, label="bystander")
            rt.taskwait()
        assert out.tolist() == [1.0, 2.0]
        assert rt.stats.timeout_kills >= 1
        assert rt.stats.task_retries >= 1
        assert rt.stats.worker_respawns >= 1
    finally:
        _drain([flag, out])


def test_timeout_retries_exhausted_poisons():
    plan = FaultPlan().stall_body("wedged", 5.0, times=4)
    rt = ProcessRuntime(num_workers=1, mode="sync", ipc_batch=1,
                        fault_plan=plan)
    rt.start()
    rt.task(apps.spin, 10.0, label="wedged", retries=0, timeout=0.25)
    with pytest.raises(TaskFailed, match="timeout") as ei:
        rt.taskwait()
    rt.shutdown()
    (label, reason, attempts), = ei.value.failures
    assert label == "wedged"
    assert attempts and attempts[0]["reason"] == "timeout"
    assert rt.stats.timeout_kills >= 1
    assert rt.stats.tasks_poisoned == 1


# ------------------------------------------------------------ done frames
def test_dropped_done_frame_recovered_by_timeout():
    """A swallowed done frame is indistinguishable from a stuck task:
    only the deadline recovers it (kill + respawn + retry)."""
    out = apps.ShmArray(6)
    try:
        plan = FaultPlan().drop_done(0, nth=1)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan) as rt:
            for i in range(6):
                rt.task(_flaky_write, out.name, i, label=f"w{i}",
                        retries=1, timeout=0.8)
            rt.taskwait()
        assert out.tolist() == [float(i + 1) for i in range(6)]
        assert rt.stats.timeout_kills >= 1
        assert rt.stats.task_retries >= 1
    finally:
        _drain([out])


def _flaky_write(out_name, i):
    apps._attach(out_name)[i] = i + 1.0


def test_delayed_done_frame_is_harmless():
    out = apps.ShmArray(4)
    try:
        plan = FaultPlan().delay_done(0, nth=1, delay_s=0.05)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan) as rt:
            for i in range(4):
                rt.task(_flaky_write, out.name, i, label=f"w{i}")
            rt.taskwait()
        assert out.tolist() == [1.0, 2.0, 3.0, 4.0]
        assert rt.stats.task_retries == 0
    finally:
        _drain([out])


# ------------------------------------------------------------ transport
def test_ring_crc_detects_corruption_and_advances():
    ring = ShmRing(capacity=256)
    try:
        ring._corrupt_next = True
        ring.push(b"poisoned-frame")
        ring.push(b"good-frame")
        with pytest.raises(RingCorruption):
            ring.pop()
        # head advanced past the bad frame: the stream continues
        assert ring.pop() == b"good-frame"
        assert ring.pop() is None
    finally:
        ring.close()
        ring.unlink()


def test_corrupt_exec_frame_respawns_worker():
    """A corrupt exec frame trips the worker-side CRC check; the worker
    exits, the supervisor respawns it, and the lost task retries."""
    out = apps.ShmArray(6)
    try:
        plan = FaultPlan().corrupt_exec_frame(0, nth=1)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan) as rt:
            for i in range(6):
                rt.task(_flaky_write, out.name, i, label=f"w{i}",
                        retries=1)
            rt.taskwait()
        assert out.tolist() == [float(i + 1) for i in range(6)]
        assert rt.stats.worker_respawns >= 1
        assert rt.stats.task_retries >= 1
    finally:
        _drain([out])


# ------------------------------------------------------------ shutdown
def test_shutdown_escalates_to_sigkill_for_zombies():
    plan = FaultPlan().stall_body("zzz", 30.0, times=4)
    plan.ignore_sigterm = True
    rt = ProcessRuntime(num_workers=2, mode="sync", ipc_batch=1,
                        fault_plan=plan, shutdown_grace=0.3)
    rt.start()
    for i in range(2):
        rt.task(apps.spin, 1.0, label=f"zzz{i}")
    time.sleep(0.3)                      # let both workers wedge
    rt._teardown()                       # no taskwait: straight down
    rt._aggregate_stats()
    assert rt.stats.zombie_workers >= 1
    assert rt.stats.leaked_shm == []


def test_clean_run_reports_no_faults():
    out = apps.ShmArray(4)
    try:
        with ProcessRuntime(num_workers=2, mode="sharded") as rt:
            for i in range(4):
                rt.task(_flaky_write, out.name, i, label=f"w{i}")
            rt.taskwait()
        st = rt.stats
        assert (st.worker_respawns, st.task_retries, st.tasks_poisoned,
                st.timeout_kills, st.transport_errors,
                st.zombie_workers) == (0, 0, 0, 0, 0, 0)
        assert st.leaked_shm == []
    finally:
        _drain([out])


# ------------------------------------------------------------ traces
def test_fault_events_land_in_trace():
    n, stages = 6, 4
    b0, b1 = _pingpong_arrays(n)
    try:
        plan = FaultPlan().kill_worker(1, after_tasks=4)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            trace=True, fault_plan=plan) as rt:
            _submit_pingpong(rt, b0.name, b1.name, n, 0, stages,
                             retries=2, spin_us=2000.0)
            rt.taskwait()
        evs = {e.ev for e in rt.stats.events}
        assert EV_WORKER_LOST in evs
        assert EV_RESPAWN in evs
        if rt.stats.trace_lost:          # tasks were in flight at kill
            assert EV_TRACE_LOST in evs
            assert EV_RETRY in evs
    finally:
        _drain([b0, b1])


def test_timeout_kill_traced():
    plan = FaultPlan().stall_body("wedged", 5.0, times=4)
    rt = ProcessRuntime(num_workers=1, mode="sync", ipc_batch=1,
                        trace=True, fault_plan=plan)
    rt.start()
    rt.task(apps.spin, 10.0, label="wedged", retries=0, timeout=0.25)
    with pytest.raises(TaskFailed):
        rt.taskwait()
    rt.shutdown()
    assert EV_TIMEOUT_KILL in {e.ev for e in rt.stats.events}


# ------------------------------------------------------------ replay plane
def test_plane_recovery_after_iter_kill():
    """Kill a worker during a replayed-plane iteration: only the dead
    worker's claimed tasks retry; the runtime falls back to live
    analysis for the rest of that iteration and completes
    serial-exact."""
    n, per_iter, iters = 6, 2, 4
    b0, b1 = _pingpong_arrays(n)
    init = b0.tolist()
    try:
        plan = FaultPlan().kill_worker_at_iter(1, nth_iter=1)
        with ProcessRuntime(num_workers=2, mode="sharded", replay=True,
                            ipc_batch=1, fault_plan=plan) as rt:
            for it in range(iters):
                # same structure each iteration (generation parity
                # repeats every 2 stages) so the plane can freeze it
                _submit_pingpong(rt, b0.name, b1.name, n, 0, per_iter,
                                 retries=1, spin_us=2000.0)
                rt.taskwait()
        final = _serial_pingpong(init, n, per_iter)
        for _ in range(iters - 1):
            final = _serial_pingpong(final, n, per_iter)
        assert b0.tolist() == final
        assert rt.stats.tasks_executed == n * per_iter * iters
        assert rt.stats.worker_respawns >= 1
        assert rt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])


def test_plane_kill_retries_zero_fails_fast():
    n = 6
    b0, b1 = _pingpong_arrays(n)
    try:
        plan = FaultPlan().kill_worker_at_iter(0, nth_iter=1)
        rt = ProcessRuntime(num_workers=2, mode="sharded", replay=True,
                            ipc_batch=1, fault_plan=plan)
        rt.start()
        raised = False
        try:
            for _ in range(4):
                _submit_pingpong(rt, b0.name, b1.name, n, 0, 2,
                                 retries=0, spin_us=2000.0)
                rt.taskwait()
        except WorkerLost:
            raised = True
        assert raised
        rt.shutdown()
        assert rt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])


# ------------------------------------------------------------ scopes
def test_scope_failure_isolated_to_owner():
    rt = TaskRuntime(num_workers=2, num_clients=2)
    rt.start()
    a, b = rt.open_scope("a"), rt.open_scope("b")
    a.task(_always_fails, label="boomA")
    b.task(apps.spin, 1.0, label="okB")
    b.taskwait()                         # unaffected tenant: no raise
    rt.taskwait()                        # root: no raise either
    with pytest.raises(TaskFailed, match="boomA"):
        a.taskwait()
    rt.shutdown()                        # error consumed: clean exit


def test_scope_deadline_expires_and_drains():
    rt = TaskRuntime(num_workers=2, num_clients=2)
    rt.start()
    slow = rt.open_scope("slow", deadline=0.15)
    ok = rt.open_scope("ok")
    for i in range(30):
        slow.task(time.sleep, 0.02, label=f"s{i}")
    for i in range(5):
        ok.task(apps.spin, 10.0, label=f"o{i}")
    ok.taskwait()                        # neighbor tenant unaffected
    with pytest.raises(ScopeExpired, match="deadline"):
        slow.taskwait()
    assert slow.drained > 0
    rt.shutdown()
    assert rt.stats.scopes_expired == 1
    assert rt.stats.scopes["slow"]["expired"].startswith("deadline")


def test_scope_budget_expires():
    rt = TaskRuntime(num_workers=2, num_clients=1, trace=True)
    rt.start()
    sc = rt.open_scope("metered", budget=0.02)
    for i in range(40):
        sc.task(time.sleep, 0.005, label=f"m{i}")
    with pytest.raises(ScopeExpired, match="budget"):
        sc.close()
    rt.shutdown()
    assert rt.stats.scopes["metered"]["budget_used_s"] > 0.02
    assert EV_SCOPE_EXPIRED in {e.ev for e in rt.stats.events}


def test_threaded_retries_and_poison():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 2:
            raise RuntimeError("transient")
        return 99

    with TaskRuntime(num_workers=2) as rt:
        t = rt.task(flaky, label="flaky", retries=1)
        rt.taskwait()
        assert t.result == 99
    assert rt.stats.task_retries == 1

    rt = TaskRuntime(num_workers=2)
    rt.start()
    rt.task(_always_fails, label="doomed", retries=1)
    with pytest.raises(TaskFailed, match="permanent failure") as ei:
        rt.taskwait()
    rt.shutdown()
    (_, _, attempts), = ei.value.failures
    assert len(attempts) == 1
    assert rt.stats.tasks_poisoned == 1


# ------------------------------------------------------------ acceptance
def test_process_faults_leave_threaded_scopes_unaffected():
    """The PR's acceptance scenario: a process-backend run surviving a
    worker kill via retries while a threaded JobScope in the same
    parent runs to completion, untouched."""
    trt = TaskRuntime(num_workers=2, num_clients=1)
    trt.start()
    sc = trt.open_scope("tenant")
    for i in range(12):
        sc.task(apps.spin, 50.0, label=f"bg{i}")

    n, stages = 6, 4
    b0, b1 = _pingpong_arrays(n)
    init = b0.tolist()
    try:
        plan = FaultPlan().kill_worker(0, after_tasks=6)
        with ProcessRuntime(num_workers=2, mode="sharded", ipc_batch=1,
                            fault_plan=plan) as prt:
            _submit_pingpong(prt, b0.name, b1.name, n, 0, stages,
                             retries=1, spin_us=300.0)
            prt.taskwait()
        assert b0.tolist() == _serial_pingpong(init, n, stages)
        assert prt.stats.worker_respawns >= 1
        assert prt.stats.leaked_shm == []
    finally:
        _drain([b0, b1])

    sc.close()                           # no raise: tenant unaffected
    trt.shutdown()
    assert trt.stats.tasks_executed >= 12
