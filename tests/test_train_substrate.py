"""Tests for the training substrate: optimizer, checkpointing (incl.
crash/corruption recovery), fault detection + elastic planning, data
pipeline determinism, and the end-to-end train loop (loss decreases,
resume is exact)."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.dispatcher import FunctionalityDispatcher
from repro.models.registry import get_model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, Prefetcher, SyntheticLM
from repro.train.fault import ElasticPlanner, HeartbeatMonitor
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   schedule)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                    weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(5))) < cfg.peak_lr
    peak = float(schedule(cfg, jnp.int32(10)))
    end = float(schedule(cfg, jnp.int32(100)))
    assert peak == pytest.approx(cfg.peak_lr, rel=1e-3)
    assert end == pytest.approx(cfg.peak_lr * cfg.min_lr_frac, rel=1e-2)


def test_clip_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    cm.save(3, tree, blocking=True)
    got = cm.restore(tree)
    assert got is not None
    step, t2 = got
    assert step == 3
    np.testing.assert_array_equal(np.asarray(t2["a"]), np.asarray(tree["a"]))
    assert t2["b"]["c"].dtype == np.asarray(tree["b"]["c"]).dtype


def test_checkpoint_survives_corruption(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((4, 4))}
    cm.save(1, tree, blocking=True)
    cm.save(2, {"w": jnp.ones((4, 4)) * 2}, blocking=True)
    # corrupt the newest checkpoint (torn write simulation)
    with open(os.path.join(str(tmp_path), "step-2", "leaf0.npy"), "wb") as f:
        f.write(b"garbage")
    got = cm.restore(tree)
    assert got is not None and got[0] == 1    # falls back to older valid


def test_checkpoint_async_via_dispatcher(tmp_path):
    disp = FunctionalityDispatcher()
    cm = CheckpointManager(str(tmp_path), dispatcher=disp)
    cm.save(5, {"w": jnp.zeros((2,))})        # enqueued, not yet on disk
    assert cm.steps() == []
    disp.notify_idle(0)                        # idle thread does the I/O
    assert cm.steps() == [5]


def test_checkpoint_keeps_last_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"w": jnp.zeros((2,))}, blocking=True)
    assert cm.steps() == [3, 4]


# ------------------------------------------------------------------ fault
def test_heartbeat_dead_and_straggler():
    t = [0.0]
    hb = HeartbeatMonitor(["h0", "h1", "h2"], timeout=10.0,
                          straggler_factor=2.0, clock=lambda: t[0])
    for h in ("h0", "h1", "h2"):
        hb.beat(h, 1, 1.0)
    t[0] = 5.0
    hb.beat("h0", 2, 1.0)
    hb.beat("h1", 2, 5.0)                      # straggler: 5x median
    assert hb.stragglers() == ["h1"]
    t[0] = 20.0
    assert "h2" in hb.dead()


def test_elastic_planner_shrinks_mesh():
    ep = ElasticPlanner(chips_per_host=4, model_axis=16)
    plan = ep.plan([f"h{i}" for i in range(64)])     # 256 chips
    assert plan.shape == (16, 16)
    plan2 = ep.plan([f"h{i}" for i in range(48)])    # lost 16 hosts
    assert plan2.shape == (12, 16)
    with pytest.raises(RuntimeError):
        ep.plan(["h0"])                              # too few for TP=16


def test_elastic_reshard_plan_covers_all_shards():
    ep = ElasticPlanner()
    plan = ep.reshard_plan(old_data=16, new_data=12)
    covered = set()
    for _, olds in plan:
        covered.update(olds)
    assert covered == set(range(16))


# ------------------------------------------------------------------- data
def test_data_deterministic_per_step():
    cfg = tiny_config("qwen2-0.5b")
    ds = SyntheticLM(cfg, DataConfig(batch=2, seq_len=16, seed=3))
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(6)["tokens"], b1["tokens"])


def test_prefetcher_async_and_sync_agree():
    cfg = tiny_config("qwen2-0.5b")
    ds = SyntheticLM(cfg, DataConfig(batch=2, seq_len=16))
    disp = FunctionalityDispatcher()
    pf = Prefetcher(ds, disp, depth=3)
    disp.notify_idle(0)                        # fill queue in "idle" time
    assert pf.fills_async == 3
    got = pf.get(0)
    np.testing.assert_array_equal(got["tokens"], ds.batch_at(0)["tokens"])


# ----------------------------------------------------------- end-to-end
def test_train_loss_decreases_and_resume_exact(tmp_path):
    from repro.launch.train import train
    d1 = str(tmp_path / "a")
    out = train("qwen2-0.5b", tiny=True, steps=24, batch=4, seq=32,
                ckpt_dir=d1, log_every=100, schedule_steps=30)
    assert out["final_loss"] < out["losses"][0]   # learning happens
    # resume: continue to 30 from the step-24 checkpoint
    out2 = train("qwen2-0.5b", tiny=True, steps=30, batch=4, seq=32,
                 ckpt_dir=d1, log_every=100, schedule_steps=30)
    # straight-through run to 30 in a fresh dir must match the resumed one
    d2 = str(tmp_path / "b")
    out3 = train("qwen2-0.5b", tiny=True, steps=30, batch=4, seq=32,
                 ckpt_dir=d2, log_every=100, schedule_steps=30)
    assert out2["losses"][-1] == pytest.approx(out3["losses"][-1], rel=1e-4)


def test_serve_engine_continuous_batching():
    from repro.launch.serve import serve
    out = serve("qwen2-0.5b", num_requests=10, clients=3, slots=3,
                max_new=4)
    assert out["requests"] == 10
    assert out["tokens"] == 40
    assert out["stats"]["admitted"] == 10


def test_serve_matches_greedy_reference():
    """Engine output must equal offline greedy decode for each request."""
    import jax.random as jr
    from repro.models.registry import get_model
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.serve_step import greedy_decode
    cfg = tiny_config("qwen2-0.5b").scaled(dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    prompts = [[5, 9, 2], [7, 1], [3, 3, 3, 3]]
    eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                      num_clients=1)
    reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    for r in reqs:
        eng.submit(r, 0)
    eng.run_until_drained()
    for p, r in zip(prompts, reqs):
        want = greedy_decode(model, params,
                             jnp.asarray([p], jnp.int32), 5, 32)
        assert r.output == list(np.asarray(want[0])), (p, r.output)
