"""Per-task event tracing (core.trace): the recorder's ring semantics,
the shared event schema on BOTH drivers (threaded lifecycle + monotone
merged timestamps; sim-vs-threaded per-task agreement on an oracle
graph), the three detrimental-pattern detectors with positive AND
negative oracles (including the replay-window false-positive fix), the
tuner feedback hook, the stats satellites (worker steals, load-cap
skips, per-scope steal rollups), and the Perfetto/Chrome exporter."""
import json
import time
from collections import Counter

import pytest

from repro.core import (DynamicTuner, RuntimeSimulator, SimTaskSpec,
                        TaskRuntime, TunerConfig)
from repro.core.sched.placement import ShardAffinePlacement
from repro.core.taskgraph_apps import sim_matmul_specs
from repro.core.trace import (AFFINITY_MISS, EV_ADMIT_DEFER, EV_CREATED,
                              EV_DELEGATE, EV_DEPS, EV_END, EV_MSG_DRAIN,
                              EV_MSG_ENQ, EV_QUIESCE, EV_READY, EV_START,
                              EV_STEAL,
                              INVERSION, NULL_TRACER, STARVATION,
                              TASK_LIFECYCLE, Finding, TraceEvent,
                              TraceRecorder, detect_affinity_misses,
                              detect_all, detect_priority_inversion,
                              detect_starvation, load_trace,
                              replay_windows, save_trace)
from repro.core.wd import DepMode, WorkDescriptor

ALL_MODES = ("sync", "dast", "ddast", "sharded")

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


def _spin(ms: float = 0.0002):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < ms:
        pass


def _chain_fanout_specs(n_chains: int = 4, depth: int = 4):
    """Small oracle graph: a root, then per-chain INOUT chains — every
    task has dependences, every label is unique."""
    specs = [SimTaskSpec(dur=40, deps=[(("root",), OUT)], label="root")]
    for c in range(n_chains):
        specs.append(SimTaskSpec(
            dur=25, deps=[(("root",), IN), (("ch", c), OUT)],
            label=f"head{c}"))
        for j in range(depth):
            specs.append(SimTaskSpec(
                dur=25, deps=[(("ch", c), INOUT)], label=f"c{c}_{j}"))
    return specs


def _mk(t, ev, wd_id=-1, slot=-1, label="", scope=None, data=None):
    return TraceEvent(t, ev, wd_id, slot, label, scope, data)


# ------------------------------------------------------------ recorder
def test_null_tracer_is_shared_and_silent():
    with TaskRuntime(num_workers=2, mode="ddast") as rt:
        rt.task(_spin)
        rt.taskwait()
        assert rt.tracer is NULL_TRACER      # one shared stub, no rings
    assert rt.stats.events == []
    assert rt.stats.trace_dropped == 0
    assert NULL_TRACER.total_appended == 0
    assert NULL_TRACER.events() == []


def test_recorder_ring_drops_oldest_per_slot():
    clock = iter(range(100))
    rec = TraceRecorder(2, clock=lambda: next(clock), capacity=4)
    wd = WorkDescriptor(func=None, label="x")
    for _ in range(7):
        rec.task_event(EV_READY, wd, 0)
    assert rec.dropped == 3
    kept = [e.t for e in rec.events()]
    assert kept == [3, 4, 5, 6]              # oldest evicted first


def test_recorder_overflow_slot_routing():
    rec = TraceRecorder(2, clock=lambda: 0.0)
    wd = WorkDescriptor(func=None, label="x")
    rec.task_event(EV_READY, wd, -1)         # unattributed producer
    rec.task_event(EV_READY, wd, 99)         # out of range
    rec.mgr_event(EV_MSG_ENQ, -1, data=("submit", 0, 1))
    assert len(rec._rings[2]) == 3           # all in the overflow ring
    assert len(rec.events()) == 3


def test_recorder_save_load_round_trip(tmp_path):
    rec = TraceRecorder(2, clock=lambda: 1.5, time_unit="us")
    wd = WorkDescriptor(func=None, label="t0")
    rec.task_event(EV_READY, wd, 0, data=("band", 3))
    rec.quiesce({"scope": None, "replay_iterations": 2})
    p = tmp_path / "run.trace"
    rec.save(str(p))
    events, meta = load_trace(str(p))
    assert meta["time_unit"] == "us" and meta["num_slots"] == 2
    assert events[0].ev == EV_READY and events[0].label == "t0"
    assert list(events[0].data) == ["band", 3]   # tuples -> lists
    assert events[1].ev == EV_QUIESCE
    assert events[1].data["replay_iterations"] == 2


def test_save_trace_helper_for_results(tmp_path):
    res = RuntimeSimulator(4, "ddast", trace=True).run(
        _chain_fanout_specs())
    p = tmp_path / "sim.trace"
    save_trace(str(p), res.events, time_unit="us")
    events, meta = load_trace(str(p))
    assert len(events) == len(res.events)
    assert meta["time_unit"] == "us"


# ----------------------------------------------- threaded trace=True
@pytest.mark.parametrize("mode", ALL_MODES)
def test_threaded_lifecycle_and_monotone_timestamps(mode):
    with TaskRuntime(num_workers=4, mode=mode, trace=True) as rt:
        for i in range(24):
            rt.task(_spin, deps=[(("r", i % 4), "inout")],
                    label=f"t{i}")
        rt.taskwait()
    events = rt.stats.events
    assert events and rt.stats.trace_dropped == 0
    ts = [e.t for e in events]
    assert ts == sorted(ts)                  # merged sort is by time
    assert all(t >= 0.0 for t in ts)         # relative to run start
    per = {}
    starts, ends = {}, {}
    for e in events:
        if e.wd_id < 0:
            continue
        if e.ev in TASK_LIFECYCLE:
            per.setdefault(e.label, Counter())[e.ev] += 1
        if e.ev == EV_START:
            starts[e.wd_id] = e.slot
        elif e.ev == EV_END:
            ends[e.wd_id] = e.slot
    for i in range(24):
        c = per[f"t{i}"]
        assert c[EV_CREATED] == c[EV_READY] == 1
        assert c[EV_START] == c[EV_END] == 1
    # a body runs start-to-end on one slot
    assert starts == ends
    # quiesce boundary stamped at the root taskwait
    assert any(e.ev == EV_QUIESCE for e in events)


def test_threaded_scope_tagging():
    with TaskRuntime(num_workers=2, mode="sync", trace=True,
                     num_clients=1) as rt:
        sc = rt.open_scope("tenant")
        for i in range(6):
            sc.task(_spin, deps=[(("A",), "inout")], label=f"s{i}")
        sc.taskwait()
        sid = sc.scope_id
        sc.close()
    tagged = [e for e in rt.stats.events
              if e.ev in TASK_LIFECYCLE and e.label.startswith("s")]
    assert tagged
    assert all(e.scope == sid for e in tagged)


# ------------------------------------- sim vs threaded schema agreement
@pytest.mark.parametrize("mode", ("ddast", "sharded"))
def test_sim_threaded_event_schema_agreement(mode):
    """Both drivers emit the same per-task event-kind multiset for the
    same logical graph (deps_resolved is per shard portion in sharded
    mode — on both drivers, since they share the router), and both
    attribute start/end of a body to one slot."""
    specs = _chain_fanout_specs()
    sim_res = RuntimeSimulator(4, mode, trace=True).run(specs)

    with TaskRuntime(num_workers=4, mode=mode, trace=True) as rt:
        for s in specs:
            rt.task(_spin, deps=[(r, m) for r, m in s.deps],
                    label=s.label)
        rt.taskwait()

    def per_label(events):
        out = {}
        for e in events:
            if e.wd_id >= 0 and e.ev in TASK_LIFECYCLE:
                out.setdefault(e.label, Counter())[e.ev] += 1
        return out

    sim_kinds = per_label(sim_res.events)
    thr_kinds = per_label(rt.stats.events)
    assert set(sim_kinds) == set(thr_kinds) == {s.label for s in specs}
    for label in sim_kinds:
        assert sim_kinds[label] == thr_kinds[label], label

    def start_end_slots(events):
        s, e_ = {}, {}
        for e in events:
            if e.ev == EV_START:
                s[e.wd_id] = e.slot
            elif e.ev == EV_END and e.wd_id in s:
                e_[e.wd_id] = e.slot
        return s, e_

    for evs in (sim_res.events, rt.stats.events):
        starts, ends = start_end_slots(evs)
        assert starts == ends


def test_sim_early_visibility_does_not_confuse_detectors():
    """The simulator's causality approximation can stamp a start with
    an earlier virtual time than the task's created/ready (a core
    running locally ahead published it 'into the past'). Detectors
    pair by wd_id, so a clean run stays clean."""
    specs = [SimTaskSpec(dur=50, deps=[(("a", 0), OUT)], label="w0")]
    for i in range(6):
        specs.append(SimTaskSpec(
            dur=30, deps=[(("a", 0), IN), ((i, 1), OUT)], label=f"r{i}"))
    res = RuntimeSimulator(4, "sync", trace=True).run(specs)
    by_label = {}
    for e in res.events:
        if e.label == "w0" and e.ev in (EV_CREATED, EV_START):
            by_label[e.ev] = e.t
    # the quirk this test is about: w0 starts "before" it is created
    assert by_label[EV_START] < by_label[EV_CREATED]
    assert detect_all(res.events) == []


# ------------------------------------------------- detectors: oracles
def _workers_present(t0=0.0):
    """Make workers 0 and 1 known to the sweep (busy maps populate at
    the first start), both idle again by t0."""
    w = WorkDescriptor(func=None, label="warm")
    return [
        _mk(t0 + 0.0, EV_START, wd_id=900, slot=0, label="warm"),
        _mk(t0 + 0.1, EV_END, wd_id=900, slot=0, label="warm"),
        _mk(t0 + 0.0, EV_START, wd_id=901, slot=1, label="warm"),
        _mk(t0 + 0.1, EV_END, wd_id=901, slot=1, label="warm"),
    ] if w else []


def test_starvation_positive_deep_deque():
    evs = _workers_present()
    # slot 1's deque piles up while worker 0 sits idle the whole span
    for i in range(5):
        evs.append(_mk(1.0 + i * 0.01, EV_READY, wd_id=i, slot=1,
                       label=f"t{i}"))
    evs.append(_mk(100.0, EV_END, wd_id=901, slot=1))   # span closer
    found = detect_starvation(evs)
    assert len(found) == 1
    f = found[0]
    assert f.kind == STARVATION and f.slot == 1
    assert not f.detail["backlog_only"]
    assert 0 in f.detail["idle_slots"]


def test_starvation_positive_stalled_backlog():
    evs = _workers_present()
    evs.append(_mk(1.0, EV_MSG_ENQ, data=("submit_batch", 0, 10)))
    evs.append(_mk(100.0, EV_MSG_DRAIN, data=("submit_batch", 0, 10)))
    found = detect_starvation(evs)
    assert len(found) == 1
    assert found[0].detail["backlog_only"]


def test_starvation_negative_draining_backlog_is_pipelining():
    """Deep mailboxes behind an ACTIVELY draining manager never flag:
    each drain closes the candidate span before it reaches min_dur."""
    evs = _workers_present()
    # prime a standing backlog well above backlog_min...
    evs.append(_mk(0.5, EV_MSG_ENQ, data=("submit_batch", 0, 20)))
    t = 1.0
    for _ in range(120):                    # ...then steady turnover
        evs.append(_mk(t, EV_MSG_ENQ, data=("submit", 0, 1)))
        evs.append(_mk(t + 0.25, EV_MSG_DRAIN, data=("submit", 0, 1)))
        t += 0.5
    assert detect_starvation(evs) == []


def test_starvation_negative_clean_sim_runs():
    for mode in ALL_MODES:
        res = RuntimeSimulator(16, mode, trace=True).run(
            sim_matmul_specs(8, dur_us=200), iterations=2)
        assert detect_starvation(res.events) == [], mode


def test_replay_window_suppresses_backlog_signal():
    """Replayed iterations are manager-silent by design: a window whose
    closing quiesce shows replay_iterations advanced must not flag
    backlog starvation (the detectors' replay false-positive fix)."""
    def timeline(iters_at_end):
        evs = _workers_present()
        evs.append(_mk(0.5, EV_QUIESCE,
                       data={"scope": None, "replay_iterations": 0}))
        # stale backlog + idle workers across (0.5, 100)
        evs.append(_mk(1.0, EV_MSG_ENQ, data=("submit_batch", 0, 10)))
        evs.append(_mk(100.0, EV_QUIESCE,
                       data={"scope": None,
                             "replay_iterations": iters_at_end}))
        return evs

    assert replay_windows(timeline(1)) == [(0.5, 100.0)]
    assert detect_starvation(timeline(1)) == []          # suppressed
    flagged = detect_starvation(timeline(0))             # live window
    assert len(flagged) == 1 and flagged[0].detail["backlog_only"]


def test_inversion_positive_and_negative():
    evs = []
    # a band-7 task ready early, never started...
    evs.append(_mk(0.0, EV_READY, wd_id=1, slot=0, label="hi",
                   data=("band", 7)))
    # ...while three band-0 tasks ready later all start before it
    for i in range(3):
        evs.append(_mk(0.5, EV_READY, wd_id=10 + i, slot=1,
                       label=f"lo{i}", data=("band", 0)))
        evs.append(_mk(1.0 + i, EV_START, wd_id=10 + i, slot=1,
                       label=f"lo{i}"))
    found = detect_priority_inversion(evs)
    assert len(found) == 1
    assert found[0].kind == INVERSION and found[0].count == 3
    # below min_count: scheduling jitter, not a pathology
    assert detect_priority_inversion(evs, min_count=4) == []
    # no bands published (live placement): detector stays silent
    res = RuntimeSimulator(8, "ddast", trace=True).run(
        sim_matmul_specs(6, dur_us=150))
    assert detect_priority_inversion(res.events) == []


def test_inversion_negative_critical_path_replay():
    """The banded lane drains highest band first, so a critical-path
    replay run is inversion-free by construction."""
    res = RuntimeSimulator(8, "ddast", trace=True, replay=True,
                           placement="critical_path").run(
        sim_matmul_specs(6, dur_us=150), iterations=3)
    assert any(e.ev == EV_READY and isinstance(e.data, tuple)
               and e.data[0] == "band" for e in res.events)
    assert detect_priority_inversion(res.events) == []


def test_affinity_positive_and_negative():
    evs = []
    for i in range(4):
        evs.append(_mk(1.0 + i, EV_READY, wd_id=i, slot=1,
                       label=f"a{i}", data="affine"))
        evs.append(_mk(2.0 + i, EV_STEAL, wd_id=i, slot=2,
                       label=f"a{i}", data=1))
        evs.append(_mk(2.1 + i, EV_START, wd_id=i, slot=2,
                       label=f"a{i}"))
    found = detect_affinity_misses(evs)
    assert len(found) == 1
    f = found[0]
    assert f.kind == AFFINITY_MISS and f.count == 4
    assert f.detail["miss_frac"] == 1.0
    # same placements executed in place: no findings
    clean = []
    for i in range(4):
        clean.append(_mk(1.0 + i, EV_READY, wd_id=i, slot=1,
                         label=f"a{i}", data="affine"))
        clean.append(_mk(2.0 + i, EV_START, wd_id=i, slot=1,
                         label=f"a{i}"))
    assert detect_affinity_misses(clean) == []
    # a miss without a steal is a benign re-pop, not a trade
    no_steal = [e for e in evs if e.ev != EV_STEAL]
    assert detect_affinity_misses(no_steal) == []


def test_detect_all_kwarg_routing():
    evs = _workers_present()
    for i in range(3):
        evs.append(_mk(1.0 + i * 0.01, EV_READY, wd_id=i, slot=1))
    evs.append(_mk(100.0, EV_END, wd_id=901, slot=1))
    assert detect_all(evs) == []                 # depth 3 < default 4
    found = detect_all(evs, starvation_depth_min=3)
    assert [f.kind for f in found] == [STARVATION]


# ------------------------------------------------- tuner feedback loop
def test_tuner_trace_hook_only_registered_when_traced():
    with TaskRuntime(num_workers=2, mode="sharded") as rt:
        DynamicTuner(rt)
        assert "trace-feedback" not in rt.dispatcher.stats()
    with TaskRuntime(num_workers=2, mode="sharded", trace=True) as rt:
        DynamicTuner(rt)
        rt.task(_spin)
        rt.taskwait()
        assert rt.dispatcher.stats()["trace-feedback"] >= 1


def test_tuner_starvation_votes_widen_and_unsettle():
    rt = TaskRuntime(num_workers=8, mode="sharded", trace=True)
    try:
        tuner = DynamicTuner(rt, TunerConfig(trace_starve_votes=2))
        tuner._shard_settled = True
        mgr0 = rt.params.max_ddast_threads
        starv = [Finding(STARVATION, 0.0, 1.0)]
        assert tuner.note_trace_verdicts(starv) is False   # 1st vote
        assert rt.params.max_ddast_threads == mgr0
        assert tuner.note_trace_verdicts(starv) is True    # 2nd: act
        assert rt.params.max_ddast_threads == mgr0 + 1
        assert tuner.shards_settled is False               # re-bracket
        acts = [a for _, a in tuner.trace_actions]
        assert acts == ["widen_managers", "unsettle_shards"]
        # the vote counter reset: the next lone verdict does nothing
        assert tuner.note_trace_verdicts(starv) is False
        # non-starvation verdicts are recorded but never move a knob
        n = len(tuner.trace_actions)
        tuner.note_trace_verdicts([Finding(AFFINITY_MISS, 0, 1)] * 5)
        assert len(tuner.trace_actions) == n
        assert len(tuner.trace_verdicts) == 8
    finally:
        rt.start()
        rt.shutdown()


def test_tuner_trace_callback_live_run():
    """End to end on real threads: the quiescence hook sweeps without
    error and only acts when the detectors actually voted."""
    rt = TaskRuntime(num_workers=4, mode="sharded", trace=True)
    tuner = DynamicTuner(rt)
    with rt:
        for it in range(2):
            for i in range(16):
                rt.task(_spin, deps=[(("r", i % 4), "inout")])
            rt.taskwait()
    assert isinstance(tuner.trace_verdicts, list)
    if not any(f.kind == STARVATION for f in tuner.trace_verdicts):
        assert tuner.trace_actions == []


# ------------------------------------------------- stats satellites
def test_worker_steals_surfaced_both_drivers():
    res = RuntimeSimulator(4, "ddast", trace=True).run(
        _chain_fanout_specs())
    assert len(res.worker_steals) == 4
    assert sum(res.worker_steals) == \
        sum(1 for e in res.events if e.ev == EV_STEAL)
    with TaskRuntime(num_workers=4, mode="ddast", trace=True) as rt:
        for i in range(24):
            rt.task(_spin, deps=[(("r", i % 4), "inout")])
        rt.taskwait()
    st = rt.stats
    assert len(st.worker_steals) == len(rt.placement.deques)
    assert sum(st.worker_steals) == \
        sum(1 for e in st.events if e.ev == EV_STEAL)
    assert st.load_cap_skips == 0            # round-robin has no cap


def test_load_cap_skips_counted_and_surfaced():
    pl = ShardAffinePlacement(2)
    hot = WorkDescriptor(func=None, deps=((("h",), INOUT),), label="w")
    pl.note_executed(hot, 0)                 # region pinned to slot 0
    for i in range(8):
        pl.push(WorkDescriptor(func=None, deps=((("h",), INOUT),),
                               label=f"w{i}"))
    assert pl.load_cap_skips > 0             # cap yielded to balance
    assert pl.stats()["load_cap_skips"] == pl.load_cap_skips
    res = RuntimeSimulator(4, "sharded", trace=True,
                           placement="shard_affine").run(
        sim_matmul_specs(6, dur_us=100))
    assert isinstance(res.load_cap_skips, int)


def test_scope_rollup_includes_steals():
    sim = RuntimeSimulator(4, "ddast", trace=True)
    res = sim.run_scopes(
        [_chain_fanout_specs(2, 2), _chain_fanout_specs(2, 2)],
        names=["a", "b"])
    for name in ("a", "b"):
        assert "steals" in res.scopes[name]
        assert res.scopes[name]["steals"] >= 0
    total = sum(res.scopes[n]["steals"] for n in ("a", "b"))
    scope_steal_events = sum(1 for e in res.events
                             if e.ev == EV_STEAL and e.scope is not None)
    assert total == scope_steal_events


def test_admission_defer_events_recorded():
    sim = RuntimeSimulator(4, "ddast", trace=True)
    res = sim.run_scopes(
        [_chain_fanout_specs(4, 3), _chain_fanout_specs(4, 3)],
        max_inflight=[1, 1], names=["a", "b"])
    defers = [e for e in res.events if e.ev == EV_ADMIT_DEFER]
    assert defers                            # cap 1 must hold tasks back
    assert all(e.scope is not None for e in defers)
    assert all(e.data["queued"] >= 1 for e in defers)


def test_sharded_mailbox_events_balance():
    """Every enqueued/delegated submit/done is eventually drained: the
    (kind, where, n) payloads sum to zero backlog at run end, per
    mailbox (blocking) or per shard request list (delegation)."""
    res = RuntimeSimulator(4, "sharded", trace=True).run(
        _chain_fanout_specs())
    backlog = {}
    for e in res.events:
        if e.ev in (EV_MSG_ENQ, EV_DELEGATE, EV_MSG_DRAIN):
            kind, where, n = e.data
            backlog[where] = backlog.get(where, 0) \
                + (-n if e.ev == EV_MSG_DRAIN else n)
    assert backlog and all(v == 0 for v in backlog.values())
    # deps_resolved is stamped per shard portion on multi-region tasks:
    # each head spans two regions, so 1 or 2 portions depending on
    # whether the region hashes collide on one shard
    per_head = Counter(e.label for e in res.events
                       if e.ev == EV_DEPS and e.label.startswith("head"))
    assert set(per_head) == {f"head{c}" for c in range(4)}
    assert all(1 <= n <= 2 for n in per_head.values())


# ------------------------------------------------------- traceview
def test_traceview_chrome_trace_structure(tmp_path):
    from repro.analysis import traceview

    res = RuntimeSimulator(4, "sharded", trace=True,
                           placement="shard_affine").run(
        _chain_fanout_specs(), iterations=2)
    p = tmp_path / "run.trace"
    save_trace(str(p), res.events, time_unit="us")
    out = traceview.main([str(p), "-o", str(tmp_path / "out.json"),
                          "--detect"])
    assert out == 0
    doc = json.loads((tmp_path / "out.json").read_text())
    evs = doc["traceEvents"]
    slices = [e for e in evs if e["ph"] == "X"]
    assert len(slices) == res.tasks          # one slice per body
    assert all(e["dur"] >= 0 for e in slices)
    assert all(e["pid"] == 0 for e in slices)
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any(n.startswith("worker") for n in names)
    counters = [e for e in evs if e["ph"] == "C"]
    assert counters and all(e["args"]["backlog"] >= 0 for e in counters)
    assert any(e["ph"] == "i" and e["name"] == "quiesce" for e in evs)
    assert doc["otherData"]["time_unit"] == "us"


def test_traceview_slice_pairing_survives_dropped_starts():
    """A ring that evicted a start event must not produce a negative
    or phantom slice."""
    from repro.analysis.traceview import to_chrome_trace
    evs = [_mk(5.0, EV_END, wd_id=1, slot=0, label="orphan"),
           _mk(6.0, EV_START, wd_id=2, slot=0, label="ok"),
           _mk(7.0, EV_END, wd_id=2, slot=0, label="ok")]
    doc = to_chrome_trace(evs, "us")
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert [s["name"] for s in slices] == ["ok"]
