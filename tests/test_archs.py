"""Per-architecture smoke tests on REDUCED same-family configs: one
forward + one train(grad) step on CPU, asserting output shapes and no
NaNs; plus decode-vs-forward equivalence (the KV-cache/recurrent-state
paths must reproduce teacher forcing)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, tiny_config
from repro.models.registry import get_model
from repro.models.layers import padded_vocab

ALL = sorted(ARCHS)


def _batch(cfg, b=2, s=16, key=0):
    toks = jax.random.randint(jax.random.key(key), (b, s), 0, 100)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.key(key + 1), (b, cfg.encoder_seq, cfg.d_model),
            cfg.jnp_dtype) * 0.1
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_smoke(name):
    cfg = tiny_config(name)
    m = get_model(cfg)
    params = m.init_params(jax.random.key(0))
    b, s = 2, 16
    logits, aux = m.forward(params, _batch(cfg, b, s))
    assert logits.shape == (b, s, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL)
def test_train_grad_smoke(name):
    cfg = tiny_config(name).scaled(dtype="float32")
    m = get_model(cfg)
    params = m.init_params(jax.random.key(0))
    batch = _batch(cfg, 2, 16)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = m.forward(p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # something actually flows to the first-layer mixer params
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_forward(name):
    # high capacity factor so MoE drops nothing (drop-free equivalence)
    cfg = tiny_config(name).scaled(dtype="float32", capacity_factor=16.0)
    m = get_model(cfg)
    params = m.init_params(jax.random.key(1))
    b, s = 2, 8
    batch = _batch(cfg, b, s, key=5)
    ref, _ = m.forward(params, batch)
    cache = m.init_cache(b, s)
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        cache = encdec.fill_cross_cache(cfg, params, cache, batch["frames"])
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, batch["tokens"][:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - ref)) < 1e-4, name


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate sizes."""
    import repro.configs as C
    expect = {
        "qwen2-72b": (60e9, 80e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "gemma2-27b": (22e9, 32e9),
        "chameleon-34b": (30e9, 38e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "xlstm-125m": (0.08e9, 0.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = C.get_config(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.1f}B params out of range"
