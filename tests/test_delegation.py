"""Delegation/combining transport (core.shards.router): MPSC request-list
properties (hypothesis where available, seeded stress always), the
dependence-ordering oracle proving delegated == blocking orderings across
the 4-policy matrix, wait-free accounting in the simulator, counter
survival across online resize, the handoffs-based tuner metric, the
per-scope band-table merge in CriticalPathPlacement, and the
scope-starvation regression (flooding tenant through ddast AND sharded
scope-fair drains)."""
import random
import threading

import numpy as np
import pytest

from repro.core import (RuntimeSimulator, SimTaskSpec, TaskRuntime)
from repro.core.autotune import DynamicTuner, TunerConfig
from repro.core.scopes.admission import FairAdmission
from repro.core.sched.placement import CriticalPathPlacement
from repro.core.shards import ShardedDependenceGraph, ShardRouter
from repro.core.taskgraph_apps import (run_matmul, run_sparselu,
                                       sim_matmul_specs,
                                       sim_sparselu_specs)
from repro.core.wd import DepMode, TaskState, WorkDescriptor

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # container without hypothesis:
    HAVE_HYPOTHESIS = False              # the seeded tests below still run

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


def _drain(router):
    while router.pending():
        router.drain_all()


def _router(num_shards=4, **kw):
    graph = ShardedDependenceGraph(num_shards=num_shards)
    ready = []
    router = ShardRouter(graph, on_ready=ready.append, **kw)
    return graph, router, ready


# ------------------------------------------------ MPSC request list unit
def test_publish_lands_in_requests_and_pending_counts_it():
    """A portion published while the shard lock is HELD (a combiner is
    busy) must sit in the MPSC request list, be visible to pending(),
    and never touch the blocking mailbox."""
    graph, router, ready = _router(num_shards=1)
    root = WorkDescriptor(func=None, label="root")
    wd = WorkDescriptor(func=None, deps=((("r",), INOUT),), parent=root)
    shard = graph.shards[0]
    assert shard.lock.try_acquire()      # impersonate a busy combiner
    try:
        router.route_submit(wd)          # trylock loses -> wait-free
        assert len(shard.requests) == 1
        assert router.pending() == 1
        assert router.mailboxes[0].pending() == 0
        assert not ready                 # nobody applied it yet
    finally:
        shard.lock.release()
    # the next competitor (here: an idle drain) applies the stranded one
    _drain(router)
    assert ready == [wd] and wd.state == TaskState.READY
    assert router.delegated_portions == 1
    assert router.pending() == 0


def test_combiner_post_release_recheck_applies_late_publication():
    """Append-during-combine linearizability, deterministically: a
    portion published while another thread is INSIDE its combine session
    is applied by that combiner's post-release re-check — no portion is
    ever stranded behind a lost trylock."""
    graph, router, ready = _router(num_shards=1)
    root = WorkDescriptor(func=None, label="root")
    a = WorkDescriptor(func=None, deps=((("a",), INOUT),), parent=root)
    b = WorkDescriptor(func=None, deps=((("b",), INOUT),), parent=root)
    shard = graph.shards[0]

    # a's publication is in the list but the lock is held by this test
    # thread, standing in for a combiner mid-session
    assert shard.lock.try_acquire()
    router.route_submit(a)
    assert len(shard.requests) == 1
    # "during the combine", b publishes too and bounces off the lock
    router.route_submit(b)
    assert len(shard.requests) == 2 and not ready
    shard.lock.release()
    # the releasing combiner's loop re-checks the list: one _try_combine
    # applies BOTH publications in one session
    applied = router._try_combine(0)
    assert applied == 2
    assert ready == [a, b]               # publication (FIFO) order kept
    assert router.delegated_portions == 2
    assert router.combined_drains == 1   # one combined critical section


def test_threaded_publishers_no_lost_or_duplicated_portions():
    """Seeded multi-producer stress: T threads publish disjoint
    independent tasks through the delegation protocol; every task must
    come out READY exactly once and the structural counters balance."""
    T, PER = 6, 80
    graph = ShardedDependenceGraph(num_shards=4)
    ready = []
    ready_lock = threading.Lock()

    def on_ready(wd):
        with ready_lock:
            ready.append(wd)

    router = ShardRouter(graph, on_ready=on_ready)
    root = WorkDescriptor(func=None, label="root")
    wds = [[WorkDescriptor(func=None, deps=(((t, i), INOUT),), parent=root)
            for i in range(PER)] for t in range(T)]
    barrier = threading.Barrier(T)

    def producer(t):
        barrier.wait()
        for wd in wds[t]:
            router.route_submit(wd)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(T)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30.0)
    _drain(router)                       # any stragglers
    assert len(ready) == T * PER, "lost or duplicated portions"
    assert len(set(id(w) for w in ready)) == T * PER
    assert all(w.state == TaskState.READY for w in ready)
    # structural accounting: every portion traversed a request list once
    assert router.delegated_portions == T * PER
    assert router.messages_processed == T * PER
    assert router.pending() == 0
    assert all(h >= 0 for h in router.lock_handoffs)


def test_threaded_chain_order_preserved_per_region():
    """Per-(parent, region) submission order survives the combiner: a
    producer's INOUT chain must become ready strictly in publication
    order even while other threads hammer the same shards."""
    graph = ShardedDependenceGraph(num_shards=2)
    ready = []
    ready_lock = threading.Lock()

    def on_ready(wd):
        with ready_lock:
            ready.append(wd)

    router = ShardRouter(graph, on_ready=on_ready)
    root = WorkDescriptor(func=None, label="root")
    CH, NOISE = 40, 120
    chain = [WorkDescriptor(func=None, deps=((("c",), INOUT),),
                            parent=root, label=f"c{i}")
             for i in range(CH)]
    noise = [WorkDescriptor(func=None, deps=(((("n", i),), INOUT),),
                            parent=root) for i in range(NOISE)]

    def chain_producer():
        for wd in chain:
            router.route_submit(wd)

    def noise_producer():
        for wd in noise:
            router.route_submit(wd)

    ts = [threading.Thread(target=chain_producer),
          threading.Thread(target=noise_producer)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=30.0)
    _drain(router)
    # retire the chain head-first; each Done must release exactly the
    # next link, in order
    seen = []
    for wd in chain:
        with ready_lock:
            got = [w for w in ready if w.label.startswith("c")]
        assert got == chain[:len(seen) + 1], "chain released out of order"
        seen.append(wd)
        router.route_done(wd)
        _drain(router)
    assert all(w.state == TaskState.COMPLETED for w in chain)


# ------------------------------------- combiner fairness-bucket staging
def test_mixed_scope_batch_split_preserves_per_scope_fifo():
    """A mixed-scope batch must be split into per-scope pieces at
    staging time: bucketing the whole batch under its first entry's
    scope lets the rotation apply the batch's other-scope tail ahead of
    that scope's earlier messages still queued in their own
    (quantum-exhausted) bucket — reordering same-(parent, region)
    Submits and resolving a later sibling's dependences first."""
    from repro.core.messages import SubmitBatchMessage
    graph, router, ready = _router(num_shards=1, drain_quantum=1)
    root_a = WorkDescriptor(func=None, label="rootA")
    root_b = WorkDescriptor(func=None, label="rootB")
    a1, a2, a3 = [WorkDescriptor(func=None, deps=((("r",), INOUT),),
                                 parent=root_a, scope=1, label=f"a{i}")
                  for i in (1, 2, 3)]
    b1 = WorkDescriptor(func=None, deps=((("b",), INOUT),),
                        parent=root_b, scope=2, label="b1")
    shard = graph.shards[0]
    assert shard.lock.try_acquire()      # strand everything in requests
    try:
        router.route_submit(a1)
        router.route_submit(a2)
        # a mixed batch whose FIRST entry is scope 2 but whose tail is
        # scope 1's NEXT chain link — the exact hazard shape
        assert not router.prepare_submit(b1)
        assert not router.prepare_submit(a3)
        router._publish(0, SubmitBatchMessage([b1, a3]), "submit_batch", 2)
    finally:
        shard.lock.release()
    assert router._try_combine(0) == 4
    # only the chain head (and the independent b1) are ready
    assert set(ready) == {a1, b1}
    # retire the chain head-first: each Done must release exactly the
    # NEXT link — under first-entry bucketing a3 would precede a2
    router.route_done(a1)
    _drain(router)
    assert a2 in ready and a3 not in ready, "batch tail jumped the chain"
    router.route_done(a2)
    _drain(router)
    assert a3 in ready
    for wd in (a3, b1):
        router.route_done(wd)
    _drain(router)
    assert graph.in_graph == 0
    assert all(w.state == TaskState.COMPLETED for w in (a1, a2, a3, b1))


def test_drain_quantum_zero_is_pure_fifo():
    """DDASTParams documents drain_quantum == 0 as 'disables the
    quantum (pure FIFO drain order)'; the router must honor that
    instead of clamping it to the strictest rotation (quantum=1)."""
    graph, router, ready = _router(num_shards=1, drain_quantum=0)
    assert router.drain_quantum == 0     # not clamped to 1
    root = WorkDescriptor(func=None, label="root")
    # scopes [1, 1, 2, 2]: a quantum=1 rotation would interleave
    # (w0, w2, w1, w3); pure FIFO keeps publication order
    wds = [WorkDescriptor(func=None, deps=(((("r", i),), INOUT),),
                          parent=root, scope=1 + i // 2, label=f"w{i}")
           for i in range(4)]
    shard = graph.shards[0]
    assert shard.lock.try_acquire()
    try:
        for wd in wds:
            router.route_submit(wd)
    finally:
        shard.lock.release()
    assert router._try_combine(0) == 4
    assert ready == wds, "quantum=0 did not drain in publication order"
    # per-scope shares are still accounted for the rollups
    assert graph.shards[0].scope_portions == {1: 2, 2: 2}


# ------------------------------------------ hypothesis property versions
if HAVE_HYPOTHESIS:

    @st.composite
    def _publication_plan(draw):
        nshards = draw(st.integers(min_value=1, max_value=4))
        nregions = draw(st.integers(min_value=1, max_value=6))
        ops = draw(st.lists(st.tuples(
            st.integers(min_value=0, max_value=nregions - 1),
            st.booleans()),                 # (region, hold_lock_first)
            min_size=1, max_size=40))
        return nshards, ops

    @given(_publication_plan())
    @settings(max_examples=60, deadline=None)
    def test_property_mpsc_no_lost_portions(plan):
        """Random interleavings of publish-while-held / publish-free:
        every published portion is applied exactly once, per-region
        chains release in submission order, and the structural counter
        equals the message count."""
        nshards, ops = plan
        graph = ShardedDependenceGraph(num_shards=nshards)
        ready = []
        router = ShardRouter(graph, on_ready=ready.append)
        root = WorkDescriptor(func=None, label="root")
        submitted = []
        for region, hold in ops:
            wd = WorkDescriptor(func=None,
                                deps=(((("r", region),), INOUT),),
                                parent=root)
            if hold:
                # publish against a held lock somewhere: emulate a busy
                # combiner on every shard so the trylock must lose
                held = [sh for sh in graph.shards
                        if sh.lock.try_acquire()]
                try:
                    router.route_submit(wd)
                finally:
                    for sh in held:
                        sh.lock.release()
            else:
                router.route_submit(wd)
            submitted.append((region, wd))
        _drain(router)
        # exactly the chain heads are ready; release the rest in order
        heads = {}
        for region, wd in submitted:
            heads.setdefault(region, []).append(wd)
        for region, chain in heads.items():
            assert chain[0] in ready
        total = 0
        for region, chain in heads.items():
            for wd in chain:
                assert wd in ready, "portion lost"
                router.route_done(wd)
                _drain(router)
            total += len(chain)
        assert len(ready) == total == len(submitted)
        assert router.delegated_portions == router.messages_processed
        assert graph.in_graph == 0

    @given(st.integers(min_value=1, max_value=64),
           st.integers(min_value=1, max_value=8))
    @settings(max_examples=40, deadline=None)
    def test_property_drain_quantum_never_drops_portions(n, quantum):
        """The scope-fair rotation inside one combine session is
        work-conserving for any quantum: all n portions apply."""
        graph = ShardedDependenceGraph(num_shards=1)
        ready = []
        router = ShardRouter(graph, on_ready=ready.append,
                             drain_quantum=quantum)
        root = WorkDescriptor(func=None, label="root")
        shard = graph.shards[0]
        assert shard.lock.try_acquire()
        try:
            for i in range(n):           # all strand in the request list
                wd = WorkDescriptor(func=None, deps=(((("r", i),), INOUT),),
                                    parent=root, scope=(i % 3) or None)
                router.route_submit(wd)
        finally:
            shard.lock.release()
        assert router._try_combine(0) == n
        assert len(ready) == n
        assert router.delegated_portions == n


# ------------------- oracle: delegated == blocking dependence orderings
def _region_events(mode, specs, delegation=True):
    """Run a dependence pattern on the real runtime with logging bodies;
    return region -> [(submit_idx, kind)] in execution order."""
    log_lock = threading.Lock()
    events = {}

    def body(idx, deps):
        with log_lock:
            for region, m in deps:
                events.setdefault(region, []).append(
                    (idx, "w" if m.writes else "r"))

    kw = {"delegation": delegation} if mode == "sharded" else {}
    with TaskRuntime(num_workers=3, mode=mode, **kw) as rt:
        for idx, spec in enumerate(specs):
            rt.task(body, idx, spec.deps, deps=spec.deps, label=spec.label)
        rt.taskwait()
    assert rt.stats.tasks_executed == len(specs)
    return events


def _canonical(events):
    """Reduce an event log to its dependence semantics: per region, the
    write order and each read's last-seen writer. Two runs with equal
    canonical forms enforced the same dependence orderings."""
    out = {}
    for region, evs in events.items():
        writes = [i for i, k in evs if k == "w"]
        assert writes == sorted(writes), (region, evs)
        last = {}
        cur = -1
        for i, k in evs:
            if k == "w":
                cur = i
            else:
                last[i] = cur
        out[region] = (tuple(writes), tuple(sorted(last.items())))
    return out


@pytest.mark.parametrize("app,specs_fn,scale", [
    ("matmul", sim_matmul_specs, 3),
    ("sparselu", sim_sparselu_specs, 5),
])
def test_delegated_matches_blocking_orderings_all_policies(app, specs_fn,
                                                           scale):
    """The ISSUE acceptance oracle: across the 4-policy matrix plus both
    sharded transports, the delegated combiner enforces byte-identical
    dependence orderings — same per-region write order, same
    read-sees-writer mapping."""
    specs = specs_fn(scale)
    runs = {
        "sync": _region_events("sync", specs),
        "dast": _region_events("dast", specs),
        "ddast": _region_events("ddast", specs),
        "sharded+delegation": _region_events("sharded", specs,
                                             delegation=True),
        "sharded+blocking": _region_events("sharded", specs,
                                           delegation=False),
    }
    ref = _canonical(runs["sync"])
    for name, evs in runs.items():
        assert _canonical(evs) == ref, f"{app}: {name} diverged from sync"


def test_delegated_matches_blocking_numerics():
    """Same numeric results, bit for bit, for delegated vs blocking vs
    sync on the paper apps."""
    rng = np.random.RandomState(11)
    a = rng.rand(64, 64).astype(np.float32)
    b = rng.rand(64, 64).astype(np.float32)
    n, bs = 96, 24
    m = rng.rand(n, n).astype(np.float32) + np.eye(n, dtype=np.float32) * n
    with TaskRuntime(num_workers=3, mode="sync") as rt:
        mm_ref = run_matmul(rt, a, b, bs=16)
        lu_ref = run_sparselu(rt, m, bs)
    with TaskRuntime(num_workers=3, mode="sharded", delegation=True) as rt:
        mm_d = run_matmul(rt, a, b, bs=16)
        lu_d = run_sparselu(rt, m, bs)
    assert rt.stats.delegated_portions > 0
    assert rt.stats.combined_drains > 0
    with TaskRuntime(num_workers=3, mode="sharded", delegation=False) as rt:
        mm_b = run_matmul(rt, a, b, bs=16)
        lu_b = run_sparselu(rt, m, bs)
    assert rt.stats.delegated_portions == 0
    np.testing.assert_array_equal(mm_d, mm_ref)
    np.testing.assert_array_equal(mm_b, mm_ref)
    np.testing.assert_array_equal(lu_d, lu_ref)
    np.testing.assert_array_equal(lu_b, lu_ref)


# ---------------------------------------------- simulator: wait-free path
def test_sim_delegation_eliminates_shard_lock_wait():
    """16 virtual cores x 8 shards: the blocking transport pays real
    shard-lock wait; delegation's hot path never blocks on it (the
    VirtualLock.delegated accounting), so total lock wait collapses."""
    specs = sim_sparselu_specs(8)
    blocking = RuntimeSimulator(16, "sharded", num_shards=8,
                                delegation=False).run(specs)
    delegated = RuntimeSimulator(16, "sharded", num_shards=8,
                                 delegation=True).run(specs)
    assert blocking.lock_wait_us > 0.0
    assert delegated.lock_wait_us <= 0.7 * blocking.lock_wait_us
    assert delegated.tasks == blocking.tasks == len(specs)
    assert delegated.delegated_portions == delegated.messages > 0
    assert blocking.delegated_portions == 0
    # determinism: the sim's combine path is replayable
    again = RuntimeSimulator(16, "sharded", num_shards=8,
                             delegation=True).run(specs)
    assert again.exec_order == delegated.exec_order
    assert again.makespan_us == delegated.makespan_us


# ---------------------------------------- counters across online resize
def test_resize_carries_delegation_counters():
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4)
    pol = rt.policy
    try:
        for i in range(16):
            rt.task(lambda: None, deps=[((i % 4,), INOUT)],
                    label=f"t{i}")
        # finish everything through the real path (see test_engine's
        # resize test) so the policy reaches a resizable quiescence
        while True:
            wd = rt.placement.pop(rt.num_workers)
            if wd is None and not pol.pending() and not pol.in_graph():
                break
            if wd is not None:
                wd.mark_finished()
                pol.complete(wd, rt.num_workers)
            pol.drain_all()
        st = pol.stats()
        assert st["delegated_portions"] > 0
        assert st["combined_drains"] > 0
        before = (st["delegated_portions"], st["combined_drains"],
                  sum(st["shard_lock_handoffs"]),
                  dict(st["scope_portions"]))
        assert pol.resize(8)
        st2 = pol.stats()
        assert st2["delegated_portions"] == before[0]
        assert st2["combined_drains"] == before[1]
        assert sum(st2["shard_lock_handoffs"]) == before[2]
        assert st2["scope_portions"] == before[3]
        # and they keep accumulating on the new partition
        for i in range(6):
            rt.task(lambda: None, deps=[((("x", i),), INOUT)])
        pol.drain_all()
        assert pol.stats()["delegated_portions"] == before[0] + 6
    finally:
        rt.shutdown()


def test_tuner_uses_handoff_metric_under_delegation():
    """With delegation on, lock waits are ~0 by construction, so the
    hill-climb must steer by combiner handoffs per message instead."""
    rt = TaskRuntime(num_workers=2, mode="sharded", num_shards=4)
    try:
        tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0,
                                             shard_min_messages=10))
        msgs, hand = [0], [0]

        def feed(handoffs_per_msg, n=100):
            msgs[0] += n
            hand[0] += int(handoffs_per_msg * n)
            return {"messages_processed": msgs[0],
                    "lock_wait_s": 0.0,       # flat: useless signal
                    "shard_lock_handoffs": [hand[0]]}

        assert tuner.consider_shard_step(feed(1.0))    # first: 4 -> 8
        assert rt.policy.num_shards == 8
        assert tuner.consider_shard_step(feed(0.4))    # better: 8 -> 16
        assert rt.policy.num_shards == 16
        assert tuner.consider_shard_step(feed(0.8))    # worse: flip back
        assert rt.policy.num_shards == 8
        assert tuner.consider_shard_step(feed(1.5))    # bracketed
        assert tuner.shards_settled
        assert rt.policy.num_shards == 16
    finally:
        rt.shutdown()


# -------------------------------------------- per-scope band-table merge
def _wd(scope=None):
    return WorkDescriptor(func=None, label="t", scope=scope)


def test_scope_band_tables_merge_into_shared_universe():
    pl = CriticalPathPlacement(2, max_bands=8)
    pl.set_replay_priorities([10.0, 5.0, 1.0], scope=1)
    pl.set_replay_priorities([4.0, 2.0], scope=2)
    assert set(pl._scope_bands) == {1, 2}
    assert pl._band_counts is not None
    assert len(pl._band_counts) == pl.max_bands   # one fixed universe
    assert pl.replay_priorities_active
    # scope 1's longest chain outranks everything of scope 2: pre-scaled
    # into the shared universe, its band must be strictly higher
    assert max(pl._scope_bands[1]) > max(pl._scope_bands[2])
    # banded push through each tenant's table, global best-first pop
    a = _wd(scope=1)
    b = _wd(scope=2)
    pl.push_replay(b, 0)                 # scope 2's best chain
    pl.push_replay(a, 0)                 # scope 1's best chain
    assert pl.priority_pushes == 2
    assert sum(pl._band_counts) == 2
    assert pl.pop(0) is a                # cross-tenant longest-chain-first
    assert pl.pop(0) is b
    assert sum(pl._band_counts) == 0


def test_scope_band_clear_is_per_tenant():
    pl = CriticalPathPlacement(2, max_bands=8)
    pl.set_replay_priorities([3.0, 1.0], scope=1)
    pl.set_replay_priorities([2.0], scope=2)
    pl.clear_replay_priorities(scope=1)
    assert 1 not in pl._scope_bands and 2 in pl._scope_bands
    # the fixed band array survives: scope 2's in-flight banded work
    # (and future publications) must keep draining
    assert pl._band_counts is not None
    wd = _wd(scope=2)
    pl.push_replay(wd, 0)
    assert pl.priority_pushes == 1
    assert pl.pop(0) is wd
    # a retired tenant's tasks degrade to the normal lane, not an error
    orphan = _wd(scope=1)
    pl.push_replay(orphan, 0)
    assert pl.priority_pushes == 1       # unchanged: normal-lane push
    assert pl.pop(0) is orphan


def test_scoped_publication_declines_mismatched_legacy_universe():
    """A single-tenant table already holds the deques at a different
    band width: reconfiguring would orphan in-flight banded entries, so
    the scoped publication is declined and that tenant's tasks flow
    through the normal lane."""
    pl = CriticalPathPlacement(2, max_bands=8)
    pl.set_replay_priorities([3.0, 2.0, 1.0])     # legacy: 3-band array
    assert len(pl._band_counts) == 3
    pl.set_replay_priorities([5.0, 1.0], scope=1)
    assert 1 not in pl._scope_bands               # declined
    wd = _wd(scope=1)
    pl.push_replay(wd, 0)
    assert pl.priority_pushes == 0                # normal lane
    assert pl.pop(0) is wd


def test_root_publication_with_live_scoped_tables_keeps_universe():
    """A root-context (scope=None) publication while scoped tables are
    live must NOT reallocate the shared band array — that would empty
    every band deque and orphan other tenants' banded in-flight tasks.
    It publishes into the fixed max_bands universe instead."""
    pl = CriticalPathPlacement(2, max_bands=8)
    pl.set_replay_priorities([5.0, 1.0], scope=1)
    inflight = _wd(scope=1)
    pl.push_replay(inflight, 0)          # banded, in flight
    assert pl.priority_pushes == 1 and sum(pl._band_counts) == 1
    pl.set_replay_priorities([3.0, 2.0, 1.0])       # root-context table
    # fixed universe untouched: same width, occupancy still counts the
    # in-flight scoped task
    assert len(pl._band_counts) == pl.max_bands
    assert sum(pl._band_counts) == 1
    assert pl.pop(0) is inflight, "scoped in-flight task orphaned"
    # the root table works, pre-scaled into the shared universe
    r = _wd()
    pl.push_replay(r, 0)
    assert pl.priority_pushes == 2
    assert pl.pop(0) is r
    # root clear with scoped tables live keeps the array too
    pl.clear_replay_priorities()
    assert pl._bands_of is None and pl._band_counts is not None
    # last tenant leaving tears the universe down
    pl.clear_replay_priorities(scope=1)
    pl.clear_replay_priorities()
    assert pl._band_counts is None


def test_concurrent_first_scoped_publications_share_one_universe():
    """Two tenants' FIRST scoped publications racing from their own
    threads must leave every deque bound to the SAME counts list (the
    unguarded check-then-act could interleave the per-deque rebinding
    loop and desync occupancy from band contents)."""
    for _ in range(20):                  # racy: give it some attempts
        pl = CriticalPathPlacement(4, max_bands=8)
        barrier = threading.Barrier(2)

        def publish(scope):
            barrier.wait()
            pl.set_replay_priorities([4.0, 2.0, 1.0], scope=scope)

        ts = [threading.Thread(target=publish, args=(s,)) for s in (1, 2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=10.0)
        assert set(pl._scope_bands) == {1, 2}
        assert len(pl._band_counts) == pl.max_bands
        for d in pl.deques:
            assert d._counts is pl._band_counts


def test_replay_sid_survives_fair_admission():
    """A scoped replayed task queues through the FairAdmission ring; the
    stashed structural id must re-enter the placement's priority path at
    admission time so the task lands in its tenant's band."""
    inner = CriticalPathPlacement(2, max_bands=8)
    fa = FairAdmission(inner)
    fa.register_scope(1, weight=1.0)
    fa.set_replay_priorities([7.0, 3.0], scope=1)
    wd = _wd(scope=1)
    fa.push_replay(wd, 0)
    # admission ran inline (window open): banded in the inner placement
    assert inner.priority_pushes == 1
    assert getattr(wd, "_replay_sid", None) is None   # stash consumed
    got = fa.pop(0)
    assert got is wd
    # un-scoped replayed tasks bypass the rings entirely
    free = _wd()
    fa.push_replay(free, 1)
    assert fa.pop(0) is free


# --------------------------------------- scope-starvation regression
def _indep(tag, k):
    return [SimTaskSpec(dur=100.0, deps=[((tag, i), DepMode.INOUT)],
                        label=f"{tag}.{i}") for i in range(k)]


@pytest.mark.parametrize("mode", ["ddast", "sharded"])
def test_flooding_tenant_weighted_grants(mode):
    """A weight-1 tenant floods 3x the victim's task count. Over the
    contended grants — the only window where weighted fairness is
    defined — the weight-2 victim must be served within ±25% of 2:1.
    Eager analysis (MIN_READY effectively off) makes admission the
    contended stage in BOTH managed modes; readiness production itself
    is kept fair by the scope-fair drains (rotating ddast queue cursor,
    per-scope combiner buckets)."""
    from repro.core import DDASTParams
    n = 60
    params = DDASTParams(min_ready_tasks=100_000)
    r = RuntimeSimulator(4, mode, params=params).run_scopes(
        [_indep("v", n), _indep("f", 3 * n)],
        weights=[2.0, 1.0], names=["victim", "flood"])
    assert r.tasks == 4 * n
    sc = r.scopes
    cg_v = sc["victim"]["contended_grants"]
    cg_f = sc["flood"]["contended_grants"]
    assert cg_v >= 20, (mode, "fairness never contended")
    ratio = cg_v / max(cg_f, 1)
    assert 1.5 <= ratio <= 2.5, (mode, ratio)
    # the scope-fair drains actually rotated: both tenants' dependence
    # portions were consumed, and the rollup surfaces the shares
    assert sc["victim"]["drained_portions"] > 0
    assert sc["flood"]["drained_portions"] > 0


@pytest.mark.parametrize("mode", ["ddast", "sharded"])
def test_flooding_tenant_cannot_starve_victim_chain(mode):
    """Latency bound: the victim is a serial INOUT chain — every link's
    readiness gates on the managed drains processing its predecessor's
    Done, so a drain monopolized by the flood would stretch the chain
    toward the full makespan. The scope-fair rotation must keep the
    victim's taskwait within 3x its uncontended (solo) makespan."""
    cn = 40
    chain = [SimTaskSpec(dur=100.0, deps=[(("c",), DepMode.INOUT)],
                         label=f"v.{i}") for i in range(cn)]
    flood = _indep("f", 180)
    solo = RuntimeSimulator(4, mode).run(chain)
    r = RuntimeSimulator(4, mode).run_scopes(
        [chain, flood], weights=[2.0, 1.0], names=["victim", "flood"])
    sc = r.scopes
    assert sc["victim"]["finish_us"] <= 3.0 * solo.makespan_us, (
        mode, sc["victim"]["finish_us"], solo.makespan_us)
